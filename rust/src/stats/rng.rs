//! Deterministic random number generators.
//!
//! Three generators:
//!
//! * [`SplitMix64`] — stateless-ish 64-bit mixer; used to derive seeds.
//! * [`XorShift128`] — fast sequential stream for simulation workloads.
//! * [`CounterRng`] — *counter-based* generator: `u(i, j, k)` is a pure
//!   function of the key and coordinates. This is the paper's shared
//!   randomness `U_i^{(j,k)}` (Alg. 1 line 2, Alg. 2 line 1): drafter and
//!   verifier (and, in the compression application, encoder and K decoders)
//!   can evaluate the *same* uniforms without communicating, which is
//!   exactly the "common random numbers" assumption of Daliri et al. [9]
//!   and of GLS.

/// SplitMix64: tiny, high-quality 64-bit mixing generator.
///
/// Used mainly for seed derivation (`SplitMix64::mix`) and as the stage
/// function inside [`CounterRng`].
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// One round of the SplitMix64 output function applied to `x`.
    #[inline]
    pub fn mix(mut x: u64) -> u64 {
        x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
        x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        x ^ (x >> 31)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xorshift128+: fast sequential PRNG for bulk simulation.
#[derive(Clone, Debug)]
pub struct XorShift128 {
    s0: u64,
    s1: u64,
}

impl XorShift128 {
    pub fn new(seed: u64) -> Self {
        // Never allow the all-zero state.
        let mut sm = SplitMix64::new(seed);
        let s0 = sm.next_u64() | 1;
        let s1 = sm.next_u64();
        Self { s0, s1 }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.s0;
        let y = self.s1;
        self.s0 = y;
        x ^= x << 23;
        self.s1 = x ^ y ^ (x >> 17) ^ (y >> 26);
        self.s1.wrapping_add(y)
    }

    /// Uniform f64 in the open interval (0, 1): never 0, never 1, so it is
    /// always safe to take `ln`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 random mantissa bits, then shift into (0,1) by adding half an ulp.
        let bits = self.next_u64() >> 11;
        (bits as f64 + 0.5) * (1.0 / 9007199254740992.0)
    }

    /// Uniform integer in `[0, n)` via Lemire-style rejection.
    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        loop {
            let x = self.next_u64();
            let (hi, lo) = {
                let wide = (x as u128) * (n as u128);
                ((wide >> 64) as u64, wide as u64)
            };
            if lo >= n || lo >= n.wrapping_neg() % n {
                return hi;
            }
        }
    }

    /// Random permutation index helper: Fisher–Yates shuffle in place.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

/// Counter-based generator: a keyed pure function from coordinates to
/// uniforms. `CounterRng` *is* the shared randomness `\mathcal{R}` of the
/// paper — both sides of the coupling evaluate it independently.
///
/// The stream is indexed by three coordinates `(slot, draft, item)` matching
/// the paper's `U_i^{(j,k)}`: `slot` = decoding step j (or 0 for one-shot
/// GLS), `draft` = list index k, `item` = alphabet symbol i.
#[derive(Clone, Copy, Debug)]
pub struct CounterRng {
    key: u64,
}

impl CounterRng {
    pub fn new(key: u64) -> Self {
        Self { key }
    }

    /// Derive an independent sub-stream (e.g. per request / per sequence).
    #[inline]
    pub fn split(&self, lane: u64) -> Self {
        Self {
            key: SplitMix64::mix(self.key ^ SplitMix64::mix(lane ^ 0xA5A5_5A5A_0F0F_F0F0)),
        }
    }

    /// Pre-mix the `(slot, draft)` prefix once, returning a [`CounterLane`]
    /// that evaluates per-item variates with a *single* remaining mix round.
    ///
    /// The three-round `raw(slot, draft, item)` recomputes the first two
    /// rounds for every vocabulary item even though they depend only on
    /// `(slot, draft)`; every inner race loop in the coupling kernel hoists
    /// them through this API. Bit-exact with the unhoisted path: the lane
    /// applies the identical constants in the identical order.
    #[inline]
    pub fn lane(&self, slot: u64, draft: u64) -> CounterLane {
        let a = SplitMix64::mix(self.key ^ slot.wrapping_mul(0xD6E8_FEB8_6659_FD93));
        let b = SplitMix64::mix(a ^ draft.wrapping_mul(0xCA5A_8263_95121157));
        CounterLane { prefix: b }
    }

    /// The lane's opaque sub-stream key — shorthand for
    /// `self.lane(slot, draft).key()`.
    ///
    /// This key is the identity the coupling kernel's panel cache (and the
    /// engine's cross-thread `PanelSlice` handoff) indexes by: it is a pure
    /// *value*, so exponentials recorded under it on one thread are valid
    /// for any other thread holding an equal key — per-item variates depend
    /// on nothing but `(key, item)`.
    #[inline]
    pub fn lane_key(&self, slot: u64, draft: u64) -> u64 {
        self.lane(slot, draft).key()
    }

    #[inline]
    fn raw(&self, slot: u64, draft: u64, item: u64) -> u64 {
        // Three mixing rounds with distinct domain constants; equivalent in
        // spirit to a 3-word Philox round but cheaper and sufficient for
        // simulation-grade uniformity (validated in tests by chi-square).
        self.lane(slot, draft).raw(item)
    }

    /// Uniform in (0, 1) at coordinates `(slot, draft, item)`.
    #[inline]
    pub fn uniform(&self, slot: u64, draft: u64, item: u64) -> f64 {
        let bits = self.raw(slot, draft, item) >> 11;
        (bits as f64 + 0.5) * (1.0 / 9007199254740992.0)
    }

    /// Exponential(1) variate at the given coordinates: `-ln U`.
    /// This is the `S_i^{(k)}` of GLS (paper §3).
    #[inline]
    pub fn exponential(&self, slot: u64, draft: u64, item: u64) -> f64 {
        -self.uniform(slot, draft, item).ln()
    }

    /// Row-major flat panel of Exp(1) variates: entry `[k * items + i]` is
    /// the variate at coordinates `(slot, k, i)` for `k < drafts`,
    /// `i < items`. One contiguous allocation instead of the former
    /// `Vec<Vec<f64>>`, with the per-row lane prefix hoisted.
    pub fn exponential_matrix(&self, slot: u64, drafts: usize, items: usize) -> Vec<f64> {
        let mut out = Vec::with_capacity(drafts * items);
        for k in 0..drafts {
            let lane = self.lane(slot, k as u64);
            for i in 0..items {
                out.push(lane.exponential(i as u64));
            }
        }
        out
    }
}

/// A `(slot, draft)` sub-stream of [`CounterRng`] with the first two mix
/// rounds pre-applied. Per-item evaluation costs one SplitMix64 round.
#[derive(Clone, Copy, Debug, Default)]
pub struct CounterLane {
    prefix: u64,
}

impl CounterLane {
    /// Opaque identity of this lane's sub-stream. Every per-item variate is
    /// a pure function of `(key, item)`, so two lanes with equal keys
    /// produce identical variates for every item — regardless of which
    /// `(rng, slot, draft)` they were derived from. The coupling kernel's
    /// panel cache relies on exactly this to reuse draft-phase
    /// exponentials during verification without any bit-exactness risk.
    #[inline]
    pub fn key(&self) -> u64 {
        self.prefix
    }

    #[inline]
    pub fn raw(&self, item: u64) -> u64 {
        SplitMix64::mix(self.prefix ^ item.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// Uniform in (0, 1) at `item` — bit-exact with
    /// `CounterRng::uniform(slot, draft, item)`.
    #[inline]
    pub fn uniform(&self, item: u64) -> f64 {
        let bits = self.raw(item) >> 11;
        (bits as f64 + 0.5) * (1.0 / 9007199254740992.0)
    }

    /// Exponential(1) at `item` — bit-exact with
    /// `CounterRng::exponential(slot, draft, item)`.
    #[inline]
    pub fn exponential(&self, item: u64) -> f64 {
        -self.uniform(item).ln()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_mix_is_deterministic_and_nontrivial() {
        assert_eq!(SplitMix64::mix(0), SplitMix64::mix(0));
        assert_ne!(SplitMix64::mix(1), SplitMix64::mix(2));
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn xorshift_uniform_in_open_unit_interval() {
        let mut rng = XorShift128::new(7);
        for _ in 0..10_000 {
            let u = rng.next_f64();
            assert!(u > 0.0 && u < 1.0);
        }
    }

    #[test]
    fn xorshift_next_below_bounds_and_coverage() {
        let mut rng = XorShift128::new(11);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = rng.next_below(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn counter_rng_is_a_pure_function_of_coordinates() {
        let rng = CounterRng::new(123);
        assert_eq!(rng.uniform(1, 2, 3), rng.uniform(1, 2, 3));
        assert_ne!(rng.uniform(1, 2, 3), rng.uniform(1, 2, 4));
        assert_ne!(rng.uniform(1, 2, 3), rng.uniform(1, 3, 3));
        assert_ne!(rng.uniform(1, 2, 3), rng.uniform(2, 2, 3));
    }

    #[test]
    fn counter_rng_split_streams_disagree() {
        let root = CounterRng::new(9);
        let a = root.split(0);
        let b = root.split(1);
        assert_ne!(a.uniform(0, 0, 0), b.uniform(0, 0, 0));
        // Splitting is itself deterministic.
        assert_eq!(root.split(5).uniform(3, 1, 2), root.split(5).uniform(3, 1, 2));
    }

    #[test]
    fn counter_rng_uniformity_chi_square() {
        // 16 bins, 16k draws; chi-square(15) 99.9th percentile ~ 37.7.
        let rng = CounterRng::new(2024);
        let mut bins = [0u32; 16];
        let n = 16_384;
        for i in 0..n {
            let u = rng.uniform(0, 0, i as u64);
            bins[(u * 16.0) as usize] += 1;
        }
        let expect = n as f64 / 16.0;
        let chi2: f64 = bins.iter().map(|&c| (c as f64 - expect).powi(2) / expect).sum();
        assert!(chi2 < 37.7, "chi2 = {chi2}");
    }

    #[test]
    fn exponential_matrix_shape_and_positivity() {
        let rng = CounterRng::new(5);
        let m = rng.exponential_matrix(3, 4, 10);
        assert_eq!(m.len(), 4 * 10);
        assert!(m.iter().all(|&s| s > 0.0));
        // Strided entry (k, i) matches the coordinate-wise evaluation.
        for k in 0..4u64 {
            for i in 0..10u64 {
                assert_eq!(m[(k * 10 + i) as usize], rng.exponential(3, k, i));
            }
        }
    }

    #[test]
    fn lane_key_is_a_pure_value_identity() {
        // Two lanes with equal keys produce identical variates for every
        // item, independently of which thread derives them — the soundness
        // premise of the panel-slice handoff.
        let rng = CounterRng::new(0xBEEF).split(7);
        assert_eq!(rng.lane_key(3, 1), rng.lane(3, 1).key());
        assert_ne!(rng.lane_key(3, 1), rng.lane_key(3, 2));
        assert_ne!(rng.lane_key(3, 1), rng.lane_key(4, 1));
        let key_here = rng.lane_key(9, 0);
        let key_there =
            std::thread::spawn(move || rng.lane_key(9, 0)).join().expect("thread");
        assert_eq!(key_here, key_there);
    }

    #[test]
    fn lane_matches_full_coordinate_path() {
        let rng = CounterRng::new(0xFEED);
        for slot in [0u64, 1, 77] {
            for draft in [0u64, 3, 9] {
                let lane = rng.lane(slot, draft);
                for item in 0..64u64 {
                    assert_eq!(lane.uniform(item), rng.uniform(slot, draft, item));
                    assert_eq!(lane.exponential(item), rng.exponential(slot, draft, item));
                }
            }
        }
    }
}
