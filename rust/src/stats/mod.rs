//! Deterministic RNG, probability distributions, and summary statistics.
//!
//! The whole reproduction is seeded: every experiment in the benches is a
//! pure function of its seed, so tables regenerate bit-identically. We ship
//! our own RNG layer because (a) the paper's common-randomness construction
//! needs a *counter-based, splittable* stream (`[`rng::CounterRng`]`) and
//! (b) no external RNG crates are available in the offline vendor set.

pub mod dist;
pub mod rng;
pub mod summary;

pub use dist::{box_muller, exponential, gumbel};
pub use rng::{CounterLane, CounterRng, SplitMix64, XorShift128};
pub use summary::{mean, sem, std_dev, OnlineStats, Summary};
