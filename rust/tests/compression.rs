//! Compression-path parity + conformance suite: the codec analogue of
//! `tests/kernel_parity.rs` and `tests/conformance.rs`.
//!
//! 1. **Kernel/scalar bit-exactness.** The workspace-backed kernel paths
//!    (`encode_with` / `decode_with`) must agree bit-for-bit with the
//!    retained scalar references across every source model (toy discrete,
//!    Gaussian, latent β-VAE stand-in), both randomness modes, and
//!    K ∈ {1, 2, 4}.
//! 2. **Service bit-exactness.** The `CompressionServer` decode pool must
//!    match the single-threaded kernel reference at every worker count —
//!    scheduling may never change the bits.
//! 3. **Statistical conformance.** The encoder-selected candidate's value
//!    marginal must be chi-square-consistent with the target `p_{W|A}`:
//!    the exponential race picks candidate i with probability
//!    `λ_i / Σ_j λ_j` (Gumbel-max over importance weights), so the
//!    selected value follows the self-normalized importance-sampling
//!    estimate of `p_{W|A}` with O(1/N) bias — far below the chi-square
//!    resolution at N = 512.
//! 4. **Mode equivalence.** At K = 1, Shared and Independent randomness
//!    are the same algorithm and must produce identical bits end-to-end.
//! 5. **Fault containment.** A panicking decode job fails only its own
//!    `(block, decoder)` slot at full batch scale; every honest slot stays
//!    bit-exact and the server keeps serving.

use std::sync::Arc;

use gls_serve::compression::codec::{
    CodecConfig, CodecWorkspace, GlsCodec, RandomnessMode, SourceModel, ToyDiscrete,
};
use gls_serve::compression::gaussian::{
    gaussian_requests, run_gaussian, run_gaussian_scalar, GaussianSource,
};
use gls_serve::compression::image::{
    image_requests, run_image, run_image_scalar, synthetic_digits, AnalyticVae, SharedLatentSource,
};
use gls_serve::compression::service::{
    run_blocks_scalar, run_blocks_workspace, BatchOutput, CompressionServer, DecoderOutcome,
    ServiceError,
};
use gls_serve::spec::types::Categorical;
use gls_serve::testkit::assert_marginal;

const MODES: [RandomnessMode; 2] = [RandomnessMode::Independent, RandomnessMode::Shared];
const KS: [usize; 3] = [1, 2, 4];

/// Batches must agree on everything observable: encoder result, every
/// decoder outcome, and the success event, block by block.
fn assert_same_batches<S>(label: &str, a: &BatchOutput<S>, b: &BatchOutput<S>) {
    assert_eq!(a.blocks.len(), b.blocks.len(), "{label}: block count");
    for (x, y) in a.blocks.iter().zip(&b.blocks) {
        assert_eq!(x.block, y.block, "{label}: block id");
        assert_eq!(x.enc, y.enc, "{label}: encoder result, block {}", x.block);
        assert_eq!(x.decoded, y.decoded, "{label}: decoder outcomes, block {}", x.block);
        assert_eq!(x.hit, y.hit, "{label}: success event, block {}", x.block);
    }
}

#[test]
fn toy_discrete_kernel_matches_scalar_across_modes_and_k() {
    let model = ToyDiscrete { flip_enc: 0.1, flip_dec: 0.3 };
    for mode in MODES {
        for k in KS {
            let cfg = CodecConfig { n_samples: 64, l_max: 4, k_decoders: k, seed: 19, mode };
            let codec = GlsCodec::new(&model, cfg);
            let mut ws = CodecWorkspace::new();
            for b in 0..40u64 {
                let a = (b % 10) as usize;
                let ctx = codec.block_context(b);
                let enc = codec.encode_with(&mut ws, &ctx, &a);
                assert_eq!(
                    enc,
                    codec.encode_scalar(&a, b),
                    "toy encode diverged (mode {mode:?}, K={k}, block {b})"
                );
                for kk in 0..k {
                    let t = ((b + kk as u64) % 10) as usize;
                    let dec = codec.decode_with(&mut ws, &ctx, &t, enc.message, kk);
                    assert_eq!(
                        dec,
                        codec.decode_scalar(&t, enc.message, kk, b),
                        "toy decode diverged (mode {mode:?}, K={k}, k={kk}, block {b})"
                    );
                }
            }
        }
    }
}

#[test]
fn gaussian_scalar_kernel_and_service_agree_bitwise() {
    let src = GaussianSource::paper_default(0.005);
    for mode in MODES {
        for k in KS {
            let cfg = CodecConfig { n_samples: 256, l_max: 4, k_decoders: k, seed: 23, mode };
            let requests = gaussian_requests(src, k, 60, 23);
            let scalar = run_blocks_scalar(&src, cfg, &requests);
            let kernel = run_blocks_workspace(&src, cfg, &requests);
            assert_same_batches(&format!("gaussian scalar/kernel mode {mode:?} K={k}"), &scalar, &kernel);
            for workers in [1, 3] {
                let mut server = CompressionServer::new(Arc::new(src), cfg, workers);
                let out = server.run_batch(requests.clone());
                assert!(out.panicked.is_empty());
                assert_same_batches(
                    &format!("gaussian service mode {mode:?} K={k} workers={workers}"),
                    &out,
                    &kernel,
                );
            }
        }
    }
}

#[test]
fn latent_scalar_kernel_and_service_agree_bitwise() {
    let imgs = synthetic_digits(70, 11);
    let vae = Arc::new(AnalyticVae::fit(&imgs[..50], 4, 0.05, 13));
    let eval = &imgs[50..];
    let shared_src = SharedLatentSource { model: Arc::clone(&vae) };
    for mode in MODES {
        for k in KS {
            let cfg = CodecConfig { n_samples: 64, l_max: 4, k_decoders: k, seed: 9, mode };
            let requests = image_requests(&*vae, eval, k, 9);
            let scalar = run_blocks_scalar(&shared_src, cfg, &requests);
            let kernel = run_blocks_workspace(&shared_src, cfg, &requests);
            assert_same_batches(&format!("latent scalar/kernel mode {mode:?} K={k}"), &scalar, &kernel);
            let mut server = CompressionServer::new(
                Arc::new(SharedLatentSource { model: Arc::clone(&vae) }),
                cfg,
                2,
            );
            let out = server.run_batch(requests.clone());
            assert!(out.panicked.is_empty());
            assert_same_batches(&format!("latent service mode {mode:?} K={k}"), &out, &kernel);
        }
    }
}

#[test]
fn encoder_selected_value_marginal_follows_enc_posterior() {
    // The encoder races min_k S_i^{(k)} / λ_i over candidates drawn from
    // the uniform prior; candidate i wins with probability λ_i / Σ_j λ_j
    // (min-stability of exponentials — K only rescales every rate). The
    // selected *value* therefore follows the SNIS estimate of p_{W|A},
    // whose bias at N = 512 candidates is O(1/N) — invisible to this
    // chi-square at 3000 trials. A crossing here means the race consumes
    // wrong RNG coordinates or mis-weights candidates, not noise.
    let model = ToyDiscrete { flip_enc: 0.2, flip_dec: 0.3 };
    let a = 3usize;
    let expected = Categorical::new(model.enc_posterior(a));
    let trials = 3000usize;
    for (k, mode) in [(1usize, RandomnessMode::Independent), (4, RandomnessMode::Independent)] {
        let cfg = CodecConfig { n_samples: 512, l_max: 4, k_decoders: k, seed: 29, mode };
        let codec = GlsCodec::new(&model, cfg);
        let mut ws = CodecWorkspace::new();
        let mut counts = vec![0usize; 10];
        for b in 0..trials as u64 {
            let ctx = codec.block_context(b);
            let enc = codec.encode_with(&mut ws, &ctx, &a);
            assert!(!enc.degenerate);
            counts[ctx.samples[enc.index]] += 1;
        }
        assert_marginal(
            &format!("encoder-selected value vs p_W|A (K={k}, {mode:?})"),
            &counts,
            &expected,
            trials,
        );
    }
}

#[test]
fn shared_and_independent_are_bit_identical_at_k1() {
    // K = 1 collapses the list: one decoder, one exponential set. The two
    // randomness modes must then be the same algorithm down to the bits,
    // end-to-end through the pipeline runners.
    let src = GaussianSource::paper_default(0.005);
    let g_ind = run_gaussian(src, 1, 8, 1 << 8, 150, 17, RandomnessMode::Independent);
    let g_sh = run_gaussian(src, 1, 8, 1 << 8, 150, 17, RandomnessMode::Shared);
    assert_eq!(g_ind.match_rate.to_bits(), g_sh.match_rate.to_bits());
    assert_eq!(g_ind.mse.to_bits(), g_sh.mse.to_bits());
    // And through the scalar references.
    let s_ind = run_gaussian_scalar(src, 1, 8, 1 << 8, 150, 17, RandomnessMode::Independent);
    assert_eq!(g_ind.match_rate.to_bits(), s_ind.match_rate.to_bits());
    assert_eq!(g_ind.mse.to_bits(), s_ind.mse.to_bits());

    let imgs = synthetic_digits(60, 4);
    let vae = AnalyticVae::fit(&imgs[..40], 4, 0.05, 7);
    let eval = &imgs[40..];
    let i_ind = run_image(&vae, eval, 1, 4, 64, 9, RandomnessMode::Independent);
    let i_sh = run_image(&vae, eval, 1, 4, 64, 9, RandomnessMode::Shared);
    let i_scal = run_image_scalar(&vae, eval, 1, 4, 64, 9, RandomnessMode::Shared);
    assert_eq!(i_ind.match_rate.to_bits(), i_sh.match_rate.to_bits());
    assert_eq!(i_ind.mse.to_bits(), i_sh.mse.to_bits());
    assert_eq!(i_ind.match_rate.to_bits(), i_scal.match_rate.to_bits());
    assert_eq!(i_ind.mse.to_bits(), i_scal.mse.to_bits());
}

/// Gaussian wrapper whose decoder panics on an infinite side observation —
/// the inner model treats the same observation as an unusable (NaN) weight,
/// so the two agree everywhere the wrapper survives.
struct PanicOnInfiniteSide {
    inner: GaussianSource,
}

impl SourceModel for PanicOnInfiniteSide {
    type Source = f64;
    type Side = f64;
    type Sample = f64;

    fn sample_prior(&self, draw: &mut dyn FnMut() -> f64) -> f64 {
        self.inner.sample_prior(draw)
    }

    fn weight_enc(&self, u: &f64, a: &f64) -> f64 {
        self.inner.weight_enc(u, a)
    }

    fn weight_dec(&self, u: &f64, t: &f64) -> f64 {
        assert!(t.is_finite(), "poisoned side observation");
        self.inner.weight_dec(u, t)
    }
}

#[test]
fn panicking_decodes_fail_only_their_slots_at_batch_scale() {
    let src = GaussianSource::paper_default(0.005);
    let cfg = CodecConfig {
        n_samples: 128,
        l_max: 4,
        k_decoders: 3,
        seed: 41,
        mode: RandomnessMode::Independent,
    };
    let mut requests = gaussian_requests(src, 3, 50, 41);
    let poisoned = [(7usize, 1usize), (23, 0), (23, 2)];
    for &(bi, kk) in &poisoned {
        requests[bi].sides[kk] = f64::INFINITY;
    }
    // Reference on the inner model: identical weights on every finite side,
    // typed fallback (not a panic) on the infinite ones.
    let reference = run_blocks_workspace(&src, cfg, &requests);

    let model = Arc::new(PanicOnInfiniteSide { inner: src });
    let mut server = CompressionServer::new(Arc::clone(&model), cfg, 4);
    let out = server.run_batch(requests.clone());

    let mut failed = out.panicked.clone();
    failed.sort_unstable();
    assert_eq!(failed, poisoned.to_vec(), "panic set must be exactly the poisoned jobs");
    let poisoned_blocks = [7usize, 23];
    for (bi, (blk, want)) in out.blocks.iter().zip(&reference.blocks).enumerate() {
        assert_eq!(blk.enc, want.enc, "encoder never sees sides, block {bi}");
        for kk in 0..3 {
            if poisoned.contains(&(bi, kk)) {
                assert_eq!(blk.decoded[kk], DecoderOutcome::Panicked);
            } else {
                assert_eq!(blk.decoded[kk], want.decoded[kk], "honest slot ({bi}, {kk}) moved");
            }
        }
        if !poisoned_blocks.contains(&bi) {
            assert_eq!(blk.hit, want.hit, "honest block {bi} success event moved");
        }
    }
    match out.ok() {
        Err(ServiceError::DecodersPanicked { failed }) => assert_eq!(failed.len(), 3),
        other => panic!("expected typed panic error, got {:?}", other.map(|b| b.len())),
    }

    // The server keeps serving clean batches bit-exactly afterwards.
    let clean = gaussian_requests(src, 3, 30, 43);
    let again = server.run_batch(clean.clone());
    assert!(again.panicked.is_empty());
    assert_same_batches("post-panic clean batch", &again, &run_blocks_workspace(&src, cfg, &clean));
}
