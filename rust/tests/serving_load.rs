//! Trace-driven serving-load drills: scaled-down failure-mode scenarios
//! from `workload::drills` replayed against the multi-worker router with
//! the server-global verify pool. Invariants gated here:
//!
//! - no lost or duplicated sequences under any scenario;
//! - failed sequences roll KV back to zero leak;
//! - the pool thread census stays flat while drills run;
//! - unaffected sequences' tokens are *bit-identical* to the no-fault
//!   run (round-robin routing + per-sequence verification randomness),
//!   so fault goodput can be compared honestly;
//! - the retry-once policy turns an injected transient pool fault into a
//!   bit-exact recovery;
//! - TTFT / per-token latency accounting matches a hand-computed oracle
//!   on a `TimedLm`-scripted trace.
//!
//! Server-spawning tests serialize on a lock so the thread census is
//! meaningful even under the default parallel test runner (CI runs this
//! binary with `--test-threads=1` regardless).

use std::sync::Mutex;
use std::time::Duration;

use gls_serve::coordinator::config::{EngineConfig, VerifyBackend};
use gls_serve::coordinator::scheduler::Scheduler;
use gls_serve::coordinator::sequence::{CancelCause, Request};
use gls_serve::coordinator::{PagedKvCache, SpecDecodeEngine};
use gls_serve::model::backend::ModelPair;
use gls_serve::model::sim::SimLm;
use gls_serve::model::TimedLm;
use gls_serve::spec::types::VerifierKind;
use gls_serve::testkit::PoisonDraft;
use gls_serve::workload::{Drill, DrillOutcome, Scenario};

const SEED: u64 = 0xA11CE;
/// Census slack: drill servers run 2 workers + 3 pool threads, plus
/// generous headroom for harness noise (matches `tests/pool_shared.rs`).
const CENSUS_SLACK: usize = 2 + 3 + 8;

static SERVE_LOCK: Mutex<()> = Mutex::new(());

fn serve_guard() -> std::sync::MutexGuard<'static, ()> {
    SERVE_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Every trace id present exactly once (sorted by `Drill::run`), nothing
/// failed, every sequence filled its full generation budget.
fn assert_complete(drill: &Drill, out: &DrillOutcome) {
    let n = drill.trace.requests.len();
    let name = drill.scenario.name();
    assert_eq!(out.report.results.len(), n, "{name}: lost or duplicated sequences");
    for (i, r) in out.report.results.iter().enumerate() {
        assert_eq!(r.id, i as u64, "{name}: id sequence has a hole or duplicate");
        assert!(!r.failed, "{name}: request {} failed", r.id);
        assert_eq!(
            r.tokens.len(),
            r.prompt_len + r.max_new_tokens,
            "{name}: request {} truncated",
            r.id
        );
    }
}

#[test]
fn drill_schedules_are_deterministic() {
    for sc in Scenario::all() {
        let a = Drill::new(sc, 9);
        let b = Drill::new(sc, 9);
        assert_eq!(a.trace, b.trace, "{}: trace not replayable", sc.name());
        assert_eq!(a.poisoned, b.poisoned, "{}: fault script not replayable", sc.name());
        for idx in [0usize, 7, 31] {
            let (ra, rb) = (a.request(idx), b.request(idx));
            assert_eq!(ra.prompt, rb.prompt);
            assert_eq!(ra.max_new_tokens, rb.max_new_tokens);
            assert_eq!(ra.verifier, rb.verifier);
        }
        let c = Drill::new(sc, 10);
        assert_ne!(a.trace, c.trace, "{}: seed must matter", sc.name());
    }
}

#[test]
fn fault_free_scenarios_lose_nothing_and_agree_bit_exactly() {
    let _g = serve_guard();
    let base = Drill::new(Scenario::NoFault, SEED);
    let base_out = base.run();
    assert_complete(&base, &base_out);
    assert!(base_out.report.goodput() > 0.0);
    if let Some(d) = base_out.census_delta() {
        assert!(d <= CENSUS_SLACK, "no-fault drill grew {d} threads");
    }
    // Bursty arrivals, KV pressure and a straggling backend change *when*
    // work happens, never *what* is decoded: payload sub-streams are
    // arrival-independent, round-robin keeps the request→worker map, and
    // verification is a pure function of the per-sequence rng lane.
    for sc in [Scenario::Bursty, Scenario::KvPressure, Scenario::Straggler] {
        let drill = Drill::new(sc, SEED);
        let out = drill.run();
        assert_complete(&drill, &out);
        if let Some(d) = out.census_delta() {
            assert!(d <= CENSUS_SLACK, "{}: drill grew {d} threads", sc.name());
        }
        for (a, b) in out.report.results.iter().zip(&base_out.report.results) {
            assert_eq!(
                a.tokens,
                b.tokens,
                "{}: request {} diverged from the no-fault run",
                sc.name(),
                a.id
            );
        }
    }
}

#[test]
fn panic_storm_contains_faults_and_keeps_honest_goodput() {
    let _g = serve_guard();
    let base_out = Drill::new(Scenario::NoFault, SEED).run();
    let storm = Drill::new(Scenario::PanicStorm, SEED);
    let out = storm.run();
    assert_eq!(out.report.results.len(), storm.trace.requests.len());
    for r in &out.report.results {
        if storm.poisoned.contains(&r.id) {
            assert!(r.failed, "poisoned request {} did not fail", r.id);
            assert_eq!(r.tokens, vec![storm.trigger], "request {} emitted past the fault", r.id);
        } else {
            assert!(!r.failed, "honest request {} failed in the storm", r.id);
            assert_eq!(
                r.tokens,
                base_out.report.results[r.id as usize].tokens,
                "honest request {} diverged under the storm",
                r.id
            );
        }
    }
    assert_eq!(out.failed_ids(), storm.poisoned, "failure set is exactly the script");
    assert_eq!(
        out.report.metrics.verify_faults,
        storm.poisoned.len() as u64,
        "one contained fault per poisoned request"
    );
    // Honest tokens are identical, so goodput may only fall through wall
    // time; a collapse means the storm stalled unaffected sequences.
    let ratio = out.report.goodput() / base_out.report.goodput();
    assert!(ratio >= 0.3, "storm goodput ratio {ratio:.3} vs no-fault");
    if let Some(d) = out.census_delta() {
        assert!(d <= CENSUS_SLACK, "panic storm grew {d} threads (pool must stay flat)");
    }
}

#[test]
fn engine_death_on_one_worker_leaves_the_other_healthy() {
    let _g = serve_guard();
    let base_out = Drill::new(Scenario::NoFault, SEED).run();
    let death = Drill::new(Scenario::EngineDeath, SEED);
    let out = death.run();
    assert_eq!(out.report.results.len(), death.trace.requests.len());
    // RoundRobin puts the even ids on worker 0 — all of them scripted to
    // die — while worker 1's odd ids must be untouched.
    for r in &out.report.results {
        if r.id % 2 == 0 {
            assert!(r.failed, "worker-0 ticket {} should have died", r.id);
        } else {
            assert!(!r.failed, "worker-1 request {} caught the death", r.id);
            assert_eq!(r.tokens.len(), r.prompt_len + r.max_new_tokens);
            assert_eq!(
                r.tokens,
                base_out.report.results[r.id as usize].tokens,
                "healthy request {} diverged",
                r.id
            );
        }
    }
    assert_eq!(out.report.metrics.verify_faults, death.poisoned.len() as u64);
    if let Some(d) = out.census_delta() {
        assert!(d <= CENSUS_SLACK, "engine death grew {d} threads");
    }
}

#[test]
fn deadline_storm_times_out_exactly_the_script_and_keeps_the_rest_bit_exact() {
    let _g = serve_guard();
    let base_out = Drill::new(Scenario::NoFault, SEED).run();
    let storm = Drill::new(Scenario::DeadlineStorm, SEED);
    let out = storm.run();
    let n = storm.trace.requests.len();
    assert_eq!(out.report.results.len(), n, "lost or duplicated sequences");
    assert!(out.shed_ids.is_empty(), "nothing sheds without an admission bound");
    for (i, r) in out.report.results.iter().enumerate() {
        assert_eq!(r.id, i as u64, "id sequence has a hole or duplicate");
        assert!(!r.failed, "a timeout is not a failure (request {})", r.id);
        if storm.deadline_zero.contains(&r.id) {
            assert_eq!(
                r.cancelled,
                Some(CancelCause::DeadlineExpired),
                "scripted request {} did not time out",
                r.id
            );
            assert_eq!(r.tokens.len(), r.prompt_len, "timed-out request {} decoded anyway", r.id);
        } else {
            assert!(r.ok(), "honest request {} did not complete cleanly", r.id);
            // Expired requests still consumed their round-robin slot at
            // admission, so the request→worker map — and therefore every
            // honest token stream — matches the no-fault run exactly.
            assert_eq!(
                r.tokens,
                base_out.report.results[r.id as usize].tokens,
                "honest request {} diverged under the deadline storm",
                r.id
            );
        }
    }
    assert_eq!(out.cancelled_ids(), storm.deadline_zero, "timeout set is exactly the script");
    assert_eq!(out.report.metrics.timed_out, storm.deadline_zero.len() as u64);
    assert_eq!(out.report.metrics.cancelled, 0);
    if let Some(d) = out.census_delta() {
        assert!(d <= CENSUS_SLACK, "deadline storm grew {d} threads");
    }
}

#[test]
fn cancel_flood_retires_exactly_the_script_with_zero_kv_leak() {
    let _g = serve_guard();
    let base_out = Drill::new(Scenario::NoFault, SEED).run();
    let flood = Drill::new(Scenario::CancelFlood, SEED);
    let out = flood.run();
    assert_eq!(out.report.results.len(), flood.trace.requests.len());
    assert!(out.shed_ids.is_empty());
    for (i, r) in out.report.results.iter().enumerate() {
        assert_eq!(r.id, i as u64);
        assert!(!r.failed, "a cancellation is not a failure (request {})", r.id);
        if flood.cancel_at_submit.contains(&r.id) {
            assert_eq!(
                r.cancelled,
                Some(CancelCause::Explicit),
                "scripted request {} was not cancelled",
                r.id
            );
            assert_eq!(r.tokens.len(), r.prompt_len, "cancelled request {} decoded anyway", r.id);
        } else {
            assert!(r.ok());
            assert_eq!(
                r.tokens,
                base_out.report.results[r.id as usize].tokens,
                "honest request {} diverged under the cancel flood",
                r.id
            );
        }
    }
    assert_eq!(out.cancelled_ids(), flood.cancel_at_submit);
    assert_eq!(out.report.metrics.cancelled, flood.cancel_at_submit.len() as u64);
    assert_eq!(out.report.metrics.timed_out, 0);
    // KV pages are checked directly by the engine-level gate in
    // `cancelled_sequence_rolls_kv_back_and_counts` (engine tests) and
    // `failed_sequences_roll_kv_back_to_zero_leak` below; here the leak
    // gate is indirect — every honest request completed its full budget.
    if let Some(d) = out.census_delta() {
        assert!(d <= CENSUS_SLACK, "cancel flood grew {d} threads");
    }
}

#[test]
fn overload_shed_is_typed_bounded_and_loses_nothing() {
    let _g = serve_guard();
    let drill = Drill::new(Scenario::OverloadShed, SEED);
    let out = drill.run();
    let n = drill.trace.requests.len();
    let bound = drill.server_cfg.admit_queue as u64;
    // The burst outruns decode (every backend pays a TimedLm latency), so
    // the bounded window must shed — and every submission ends as exactly
    // one typed outcome: a terminal result or a recorded shed.
    assert!(!out.shed_ids.is_empty(), "overload burst never shed");
    assert_eq!(
        out.report.results.len() + out.shed_ids.len(),
        n,
        "submissions lost: {} served + {} shed != {n}",
        out.report.results.len(),
        out.shed_ids.len()
    );
    for r in &out.report.results {
        assert!(!out.shed_ids.contains(&r.id), "request {} both shed and served", r.id);
        assert!(r.ok(), "admitted request {} did not complete cleanly", r.id);
        assert_eq!(r.tokens.len(), r.prompt_len + r.max_new_tokens, "request {} truncated", r.id);
    }
    // (No bit-exact comparison against no-fault here: sheds consume no
    // round-robin slot, so the request→worker map legitimately shifts.)
    assert_eq!(out.report.metrics.shed_full, out.shed_ids.len() as u64);
    assert_eq!(out.report.metrics.shed_expired, 0);
    assert!(
        out.report.metrics.queue_peak >= 1 && out.report.metrics.queue_peak <= bound,
        "queue peak {} outside [1, {bound}]",
        out.report.metrics.queue_peak
    );
    assert_eq!(out.report.metrics.completed, out.report.results.len() as u64);
    if let Some(d) = out.census_delta() {
        assert!(d <= CENSUS_SLACK, "overload shed grew {d} threads");
    }
}

#[test]
fn drain_under_storm_settles_every_submission_exactly_once() {
    let _g = serve_guard();
    let base_out = Drill::new(Scenario::NoFault, SEED).run();
    let drill = Drill::new(Scenario::DrainUnderStorm, SEED);
    let out = drill.run();
    let submitted = drill.drain_after.expect("drain scenario");
    assert!(out.shed_ids.is_empty());
    assert_eq!(
        out.report.results.len(),
        submitted,
        "every submitted id must land exactly one terminal state"
    );
    for (i, r) in out.report.results.iter().enumerate() {
        assert_eq!(r.id, i as u64, "id sequence has a hole or duplicate");
        // Terminal states are mutually exclusive by construction; spell it
        // out so a regression reads as a gate failure, not a logic puzzle.
        let terminals = usize::from(r.ok())
            + usize::from(r.failed)
            + usize::from(r.cancelled.is_some());
        assert_eq!(terminals, 1, "request {} has {terminals} terminal states", r.id);
        if r.failed {
            assert!(drill.poisoned.contains(&r.id), "only poisoned requests may fail");
        }
        if r.ok() {
            assert_eq!(r.tokens.len(), r.prompt_len + r.max_new_tokens);
            assert_eq!(
                r.tokens,
                base_out.report.results[r.id as usize].tokens,
                "honest completed request {} diverged under drain",
                r.id
            );
        }
    }
    let cancelled = out.report.results.iter().filter(|r| r.cancelled.is_some()).count() as u64;
    assert_eq!(out.report.metrics.cancelled + out.report.metrics.timed_out, cancelled);
    assert_eq!(out.report.metrics.completed, submitted as u64);
    // NOTE: verify_faults may be less than poisoned.len() — a poisoned
    // request cancelled before its fault fires retires Cancelled, and
    // cancellation deliberately wins over the fault path.
    assert!(out.report.metrics.verify_faults <= drill.poisoned.len() as u64);
    if let Some(d) = out.census_delta() {
        assert!(d <= CENSUS_SLACK, "drain-under-storm grew {d} threads");
    }
}

#[test]
fn composed_fault_drill_contains_overlapping_failure_modes() {
    let _g = serve_guard();
    let base_out = Drill::new(Scenario::NoFault, SEED).run();
    let drill = Drill::new(Scenario::ComposedFault, SEED);
    let out = drill.run();
    assert_eq!(out.report.results.len(), drill.trace.requests.len());
    for r in &out.report.results {
        if drill.poisoned.contains(&r.id) {
            assert!(r.failed, "poisoned request {} did not fail", r.id);
            assert_eq!(r.tokens, vec![drill.trigger], "request {} emitted past the fault", r.id);
        } else {
            assert!(r.ok(), "honest request {} caught a composed fault", r.id);
            // Panic storm + KV pressure + straggler change when work
            // happens and which sequences roll back, never what honest
            // sequences decode.
            assert_eq!(
                r.tokens,
                base_out.report.results[r.id as usize].tokens,
                "honest request {} diverged under composed faults",
                r.id
            );
        }
    }
    assert_eq!(out.failed_ids(), drill.poisoned, "failure set is exactly the script");
    assert_eq!(out.report.metrics.verify_faults, drill.poisoned.len() as u64);
    assert!(out.report.goodput() > 0.0);
    if let Some(d) = out.census_delta() {
        assert!(d <= CENSUS_SLACK, "composed-fault drill grew {d} threads");
    }
}

#[test]
fn failed_sequences_roll_kv_back_to_zero_leak() {
    // Engine-level drill: drive the scheduler directly so the KV cache is
    // inspectable after a storm of contained verification faults.
    let _g = serve_guard();
    let trigger = 9_999u32;
    let (d, t) = SimLm::pair(64, 41, 2.0);
    let cfg = EngineConfig {
        verifier: VerifierKind::Gls,
        num_drafts: 3,
        block_len: 4,
        max_seq_len: 256,
        parallel_threshold: 0,
        verify_workers: 2,
        verify_backend: VerifyBackend::Pool,
        ..EngineConfig::default()
    };
    let mut eng = SpecDecodeEngine::new(
        cfg,
        ModelPair::new(Box::new(PoisonDraft { inner: d, trigger }), Box::new(t)),
        PagedKvCache::new(64, 16),
    );
    let mut sched = Scheduler::new(8);
    let poisoned = [2u64, 5, 8];
    for i in 0..12u64 {
        let req = if poisoned.contains(&i) {
            Request::new(i, vec![trigger], 10).with_verifier(Some(VerifierKind::FaultInjection))
        } else {
            Request::new(i, vec![1, (i % 7) as u32], 10)
        };
        sched.submit(req);
    }
    let mut results = sched.run_to_completion(&mut eng);
    results.sort_by_key(|r| r.id);
    assert_eq!(results.len(), 12);
    for r in &results {
        if poisoned.contains(&r.id) {
            assert!(r.failed);
            assert_eq!(r.tokens, vec![trigger]);
        } else {
            assert!(!r.failed);
            assert_eq!(r.tokens.len(), 2 + 10);
        }
    }
    assert_eq!(eng.kv.used_pages(), 0, "failed sequences leaked KV pages");
    eng.kv.check_invariants().expect("KV invariants after fault storm");
    assert_eq!(eng.metrics.verify_faults, poisoned.len() as u64);
}

#[test]
fn retry_once_drill_recovers_a_transient_fault_bit_exactly() {
    let _g = serve_guard();
    let baseline = Drill::new(Scenario::NoFault, SEED).run();

    // Retry on + one armed transient fault: the batch recovers and the
    // whole run is bit-identical to the clean baseline.
    let mut drill = Drill::new(Scenario::NoFault, SEED);
    drill.engine_cfg.retry_transient_faults = true;
    drill.inject_transient_faults = 1;
    let recovered = drill.run();
    assert_complete(&drill, &recovered);
    for (a, b) in recovered.report.results.iter().zip(&baseline.report.results) {
        assert_eq!(a.tokens, b.tokens, "request {} not recovered bit-exactly", a.id);
    }
    assert_eq!(recovered.report.metrics.verify_retries, 1, "exactly one retry submitted");
    assert_eq!(recovered.report.metrics.verify_retries_recovered, 1);
    assert_eq!(recovered.report.metrics.verify_faults, 0, "recovery must not count a fault");

    // Control: same fault with the policy off fails exactly one sequence.
    let mut control = Drill::new(Scenario::NoFault, SEED);
    control.inject_transient_faults = 1;
    let broken = control.run();
    assert_eq!(broken.failed_ids().len(), 1, "one transient fault, one failed sequence");
    assert_eq!(broken.report.metrics.verify_faults, 1);
    assert_eq!(broken.report.metrics.verify_retries, 0);
}

#[test]
fn latency_accounting_matches_timed_backend_oracle() {
    // TimedLm makes wall time predictable: every target forward costs at
    // least 3ms, every draft forward at least 200µs, so TTFT and
    // per-token latency have hand-computable lower bounds.
    let _g = serve_guard();
    let target_lat = Duration::from_millis(3);
    let (d, t) = SimLm::pair(32, 5, 1.5);
    let cfg = EngineConfig {
        verifier: VerifierKind::Gls,
        num_drafts: 2,
        block_len: 4,
        max_seq_len: 128,
        ..EngineConfig::default()
    };
    let mut eng = SpecDecodeEngine::new(
        cfg,
        ModelPair::new(
            Box::new(TimedLm::new(d, Duration::from_micros(200), 64)),
            Box::new(TimedLm::new(t, target_lat, 64)),
        ),
        PagedKvCache::new(1024, 16),
    );
    let mut sched = Scheduler::new(4);
    sched.submit(Request::new(0, vec![1, 2], 8));
    sched.submit(Request::new(1, vec![3, 4], 8));
    let results = sched.run_to_completion(&mut eng);
    assert_eq!(results.len(), 2);
    let mut max_tok = 0.0f64;
    for r in &results {
        let ttft = r.ttft.expect("generating sequence must stamp TTFT");
        // The first token cannot land before one target verification call.
        assert!(ttft >= target_lat, "request {}: TTFT {ttft:?} beat the oracle", r.id);
        assert!(ttft <= r.latency);
        assert!(
            r.latency >= target_lat * r.target_calls as u32,
            "request {}: latency {:?} < {} target calls x {target_lat:?}",
            r.id,
            r.latency,
            r.target_calls
        );
        let gen = r.tokens.len() - r.prompt_len;
        assert_eq!(gen, 8);
        max_tok = max_tok.max(r.latency.as_secs_f64() / gen as f64);
    }
    assert_eq!(eng.metrics.ttft.count(), 2);
    assert_eq!(eng.metrics.token_latency.count(), 2);
    // The histogram's max per-token sample sits within bucket resolution
    // of the slowest request's latency/generated ratio.
    let q = eng.metrics.token_latency.quantile(1.0);
    assert!(
        q >= 0.9 * max_tok && q <= 1.3 * max_tok,
        "token-latency histogram {q} vs oracle {max_tok}"
    );
    // Counters are monotone across a second batch on the same engine.
    let mut sched2 = Scheduler::new(4);
    sched2.submit(Request::new(10, vec![5], 6));
    sched2.run_to_completion(&mut eng);
    assert_eq!(eng.metrics.ttft.count(), 3);
    assert_eq!(eng.metrics.token_latency.count(), 3);
}
