//! Server-global verify-pool integration suite: many router workers
//! submitting concurrent batches through ONE shared `VerifyPool` for many
//! blocks must (a) emit bit-exactly the tokens the serial oracle emits,
//! (b) keep the process's thread count flat — verify threads scale with
//! the pool size, not `workers × verify_workers` — and (c) contain
//! verification faults to the offending request.

use std::sync::Arc;

use gls_serve::coordinator::config::{PoolScope, VerifyBackend};
use gls_serve::coordinator::pool::VerifyPool;
use gls_serve::coordinator::router::{Router, RoutingPolicy};
use gls_serve::coordinator::scheduler::Scheduler;
use gls_serve::coordinator::sequence::{Request, RequestResult};
use gls_serve::coordinator::{EngineConfig, PagedKvCache, ServerConfig, SpecDecodeEngine};
use gls_serve::model::backend::ModelPair;
use gls_serve::model::sim::SimLm;
use gls_serve::spec::types::VerifierKind;
// Census (None off-Linux → assertions skipped, bit-exactness ones never
// are) and the poisoned draft rig are shared with the unit suites and the
// perf bench through testkit.
use gls_serve::testkit::{thread_census, PoisonDraft};

const WORKERS: usize = 4;
const VERIFY_WORKERS: usize = 3;

fn serve_cfgs(scope: PoolScope, backend: VerifyBackend) -> (ServerConfig, EngineConfig) {
    let sc = ServerConfig {
        workers: WORKERS,
        max_batch: 8,
        batch_deadline: std::time::Duration::from_millis(1),
        max_running: 16,
        kv_pages: 4096,
        kv_page_size: 16,
        pool_scope: scope,
        ..ServerConfig::default()
    };
    let ec = EngineConfig {
        verifier: VerifierKind::Gls,
        num_drafts: 3,
        block_len: 4,
        max_seq_len: 256,
        // Force fan-out on every multi-sequence batch so the pools (shared
        // or per-engine) actually carry the verification load.
        parallel_threshold: 0,
        verify_workers: VERIFY_WORKERS,
        verify_backend: backend,
        ..EngineConfig::default()
    };
    (sc, ec)
}

fn sim_pair(_w: usize) -> ModelPair {
    let (d, t) = SimLm::pair(64, 41, 2.0);
    ModelPair::new(Box::new(d), Box::new(t))
}

/// Run a workload through a router, sampling the thread census while the
/// run is in flight. Returns (results sorted by id, max census observed).
fn serve_with_census(
    sc: &ServerConfig,
    ec: &EngineConfig,
    n_requests: u64,
    max_new: usize,
) -> (Vec<RequestResult>, Option<usize>) {
    let mut router = Router::start(sc, ec, RoutingPolicy::RoundRobin, sim_pair);
    for i in 0..n_requests {
        router.submit(Request::new(i, vec![1, (i % 7) as u32], max_new));
    }
    let mut results = Vec::with_capacity(n_requests as usize);
    let mut peak = thread_census();
    while results.len() < n_requests as usize {
        match router.results_rx.recv_timeout(std::time::Duration::from_millis(5)) {
            Ok(res) => results.push(res),
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
            Err(e) => panic!("worker dropped: {e}"),
        }
        if let (Some(p), Some(now)) = (peak, thread_census()) {
            peak = Some(p.max(now));
        }
    }
    router.shutdown();
    results.sort_by_key(|r| r.id);
    (results, peak)
}

#[test]
fn shared_pool_stress_bit_exact_and_thread_flat() {
    let n_requests = 32u64;
    let max_new = 40; // ~8 blocks per sequence: many blocks per worker
    let baseline = thread_census();

    // --- Server-global shared pool. ---------------------------------------
    let (sc_shared, ec_pool) = serve_cfgs(PoolScope::Server, VerifyBackend::Pool);
    let (shared, shared_peak) = serve_with_census(&sc_shared, &ec_pool, n_requests, max_new);

    // --- Per-engine pools (the PR 4 topology). ----------------------------
    let mid = thread_census();
    let (sc_engine, _) = serve_cfgs(PoolScope::Engine, VerifyBackend::Pool);
    let (per_engine, engine_peak) = serve_with_census(&sc_engine, &ec_pool, n_requests, max_new);

    // --- Serial oracle. ---------------------------------------------------
    let (sc_serial, ec_serial) = serve_cfgs(PoolScope::Server, VerifyBackend::Serial);
    let (serial, _) = serve_with_census(&sc_serial, &ec_serial, n_requests, max_new);

    // Bit-exactness across execution topologies: RoundRobin gives every
    // run the identical request→worker assignment, and verification is a
    // pure function of the per-sequence randomness lane.
    assert_eq!(shared.len(), serial.len());
    for ((a, b), c) in shared.iter().zip(&per_engine).zip(&serial) {
        assert_eq!(a.id, c.id);
        assert!(!a.failed && !b.failed && !c.failed);
        assert_eq!(a.tokens, c.tokens, "request {}: shared pool diverged from serial", a.id);
        assert_eq!(b.tokens, c.tokens, "request {}: per-engine pool diverged from serial", b.id);
    }

    // Thread census (Linux): the shared-pool server runs on
    // `workers + pool` threads; per-engine pooling spawns a pool per
    // worker. The margin (workers × verify − verify = 8 threads at this
    // shape) dwarfs harness noise from concurrently running tests.
    if let (Some(base), Some(sp), Some(m), Some(ep)) = (baseline, shared_peak, mid, engine_peak) {
        let shared_delta = sp.saturating_sub(base);
        let engine_delta = ep.saturating_sub(m);
        assert!(
            shared_delta <= WORKERS + VERIFY_WORKERS + 8,
            "shared-pool serving grew {shared_delta} threads (> workers {WORKERS} + pool {VERIFY_WORKERS} + slack)"
        );
        assert!(
            engine_delta >= shared_delta + 2,
            "per-engine pools ({engine_delta} new threads) should exceed the \
             shared pool ({shared_delta}) by at least the de-duplicated pool threads"
        );
    }
}

#[test]
fn shared_pool_has_no_thread_growth_across_blocks() {
    // The shared pool spawns eagerly at Router::start; decoding many
    // blocks afterwards must not create any further threads (the old
    // scoped-spawn path spawned per block).
    let (sc, ec) = serve_cfgs(PoolScope::Server, VerifyBackend::Pool);
    let mut router = Router::start(&sc, &ec, RoutingPolicy::RoundRobin, sim_pair);
    let after_start = thread_census();
    for i in 0..24u64 {
        router.submit(Request::new(i, vec![2, (i % 5) as u32], 30));
    }
    let mut peak = thread_census();
    for _ in 0..24 {
        let res = router.results_rx.recv().expect("worker alive");
        assert!(!res.failed);
        if let (Some(p), Some(now)) = (peak, thread_census()) {
            peak = Some(p.max(now));
        }
    }
    let pool = Arc::clone(router.verify_pool().expect("server-global pool"));
    router.shutdown();
    if let (Some(start), Some(p)) = (after_start, peak) {
        assert!(
            p <= start + 2,
            "thread count grew from {start} to {p} while serving (should be flat)"
        );
    }
    // All four workers verified through the one pool.
    let active: usize = (0..WORKERS as u64)
        .filter(|&w| pool.engine_stats(w).jobs > 0)
        .count();
    assert_eq!(active, WORKERS, "not every router worker used the shared pool");
}

#[test]
fn faulting_requests_fail_alone_through_the_shared_pool() {
    // Poisoned requests panic their verify jobs on the shared pool's
    // workers; the pool and every honest request (including ones from the
    // same worker's batches) must be unaffected.
    let trigger = 9_999u32;
    let (sc, mut ec) = serve_cfgs(PoolScope::Server, VerifyBackend::Pool);
    ec.verifier = VerifierKind::FaultInjection; // GLS + marker-triggered panic
    let mut router = Router::start(&sc, &ec, RoutingPolicy::RoundRobin, |_| {
        let (d, t) = SimLm::pair(64, 41, 2.0);
        ModelPair::new(Box::new(PoisonDraft { inner: d, trigger }), Box::new(t))
    });
    let n = 12u64;
    let poisoned = [3u64, 7u64];
    for i in 0..n {
        let prompt = if poisoned.contains(&i) { vec![trigger] } else { vec![1, (i % 7) as u32] };
        router.submit(Request::new(i, prompt, 16));
    }
    let mut results: Vec<RequestResult> = (0..n)
        .map(|_| router.results_rx.recv().expect("a fault must never kill a worker"))
        .collect();
    let pool = Arc::clone(router.verify_pool().expect("server-global pool"));
    let metrics = router.shutdown();
    results.sort_by_key(|r| r.id);
    for r in &results {
        if poisoned.contains(&r.id) {
            assert!(r.failed, "poisoned request {} did not fail", r.id);
            assert_eq!(r.tokens, vec![trigger], "request {} emitted past the fault", r.id);
        } else {
            assert!(!r.failed, "honest request {} failed", r.id);
            assert_eq!(r.tokens.len(), 2 + 16, "honest request {} truncated", r.id);
        }
    }
    // Exactly one contained fault per poisoned request (counted on
    // whichever path — pool worker or engine-thread serial fallback for a
    // one-sequence batch — ran the job).
    assert_eq!(metrics.verify_faults, poisoned.len() as u64, "engine fault accounting");
    let pool_faults: u64 = (0..WORKERS as u64).map(|w| pool.engine_stats(w).faults).sum();
    assert!(pool_faults <= poisoned.len() as u64, "pool fault over-count");
}

#[test]
fn slice_bank_moves_recycling_capacity_across_engines_bit_exactly() {
    // Two engines share one pool (tags 0/1). Engine 0 decodes a wide
    // batch, then a narrow one — the narrow block's lease pass banks the
    // surplus panel slices in the pool's SliceBank. Engine 1's first wide
    // batch starts with a dry local recycler, so it must lease the banked
    // slices (cross-engine reuse) and still emit bit-exactly the tokens
    // of an identically seeded solo engine: banked slices are buffer
    // capacity only, never state.
    let (_, ec) = serve_cfgs(PoolScope::Server, VerifyBackend::Pool);
    let pool = Arc::new(VerifyPool::new(VERIFY_WORKERS));
    let mk_engine = || {
        let (d, t) = SimLm::pair(64, 41, 2.0);
        SpecDecodeEngine::new(
            ec.clone(),
            ModelPair::new(Box::new(d), Box::new(t)),
            PagedKvCache::new(4096, 16),
        )
    };
    let run = |eng: &mut SpecDecodeEngine, ids: std::ops::Range<u64>| {
        let mut sched = Scheduler::new(16);
        for i in ids {
            sched.submit(Request::new(i, vec![1, (i % 7) as u32], 24));
        }
        let mut res = sched.run_to_completion(eng);
        res.sort_by_key(|r| r.id);
        res
    };

    let mut a = mk_engine();
    a.attach_shared_pool(Arc::clone(&pool), 0);
    run(&mut a, 0..6); // wide: primes the local recycler with 6 slices
    run(&mut a, 6..8); // narrow: leases 2, banks the surplus for siblings
    assert!(!pool.slice_bank().is_empty(), "engine 0 banked no surplus slices");
    assert_eq!(pool.slice_bank().cross_engine_reuses(), 0, "no sibling has leased yet");

    let mut b = mk_engine();
    b.attach_shared_pool(Arc::clone(&pool), 1);
    let pooled = run(&mut b, 100..104);
    assert!(
        pool.slice_bank().cross_engine_reuses() >= 1,
        "engine 1 never leased a banked slice from engine 0"
    );

    let mut solo = mk_engine();
    let serial = run(&mut solo, 100..104);
    assert_eq!(pooled.len(), serial.len());
    for (x, y) in pooled.iter().zip(&serial) {
        assert!(!x.failed && !y.failed);
        assert_eq!(x.tokens, y.tokens, "request {} diverged via banked slices", x.id);
    }
}
