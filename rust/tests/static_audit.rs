//! Tier-1 static audit: the repo lint over `rust/src`, the lane-registry
//! contract checks, and the lane-convention property tests.
//!
//! This is the CI gate for the invariant layer in `src/analysis/`: it fails
//! when a forbidden idiom lands (NaN-unsafe comparison, poison-propagating
//! lock, stray spawn, unregistered lane construction), when the allowlist
//! goes stale, or when a registered lane layout develops an overlap.

use std::collections::BTreeSet;
use std::path::PathBuf;

use gls_serve::analysis::lanes::{self, EngineLaneProfile, LaneError};
use gls_serve::analysis::repo_lint::{self, RuleId, ALLOWLIST};
use gls_serve::spec::types::VerifierKind;
use gls_serve::stats::rng::CounterRng;

fn src_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("src")
}

/// The whole tree is lint-clean modulo the checked-in allowlist, and the
/// allowlist has no stale entries (it can only shrink).
#[test]
fn repo_lint_is_clean_with_current_allowlist() {
    let findings = repo_lint::scan_dir(&src_root()).expect("scan rust/src");
    let (open, stale) = repo_lint::apply_allowlist(&findings, ALLOWLIST);
    if !open.is_empty() {
        let mut msg = String::from("repo lint violations (fix or add a justified allowlist entry):\n");
        for f in &open {
            msg.push_str(&format!("  {f}\n"));
        }
        panic!("{msg}");
    }
    if !stale.is_empty() {
        let mut msg = String::from("stale allowlist entries (matched nothing — remove them):\n");
        for a in &stale {
            msg.push_str(&format!(
                "  [{}] {} contains {:?} — {}\n",
                a.rule.name(),
                a.file_suffix,
                a.contains,
                a.why
            ));
        }
        panic!("{msg}");
    }
}

/// Acceptance criterion: the registry covers every `rng.lane(` call site —
/// the set of files with active `.lane(` calls equals the blessed set
/// exactly. A new lane consumer must register here; a blessed module that
/// stops constructing lanes must be un-blessed.
#[test]
fn lane_registry_covers_every_lane_call_site() {
    let files = repo_lint::lane_call_files(&src_root()).expect("scan rust/src");
    let blessed: BTreeSet<String> = lanes::BLESSED_LANE_MODULES
        .iter()
        .map(|s| s.to_string())
        .collect();
    assert_eq!(
        files, blessed,
        "files with .lane( call sites != lanes::BLESSED_LANE_MODULES \
         (left: actual, right: registry)"
    );
}

/// Every verifier kind's lane profile checks out over a K grid, as do the
/// bilateral and codec layouts — the registry's own tier-1 contract.
#[test]
fn registered_lane_layouts_are_overlap_free() {
    let mut kinds: Vec<VerifierKind> = VerifierKind::all().to_vec();
    kinds.push(VerifierKind::FaultInjection);
    for k in [1usize, 2, 3, 4, 8, 16, 64] {
        for &kind in &kinds {
            lanes::check_engine_profile(lanes::engine_profile_of(kind), k)
                .unwrap_or_else(|e| panic!("{kind:?} K={k}: {e}"));
        }
        for m in [1usize, 2, 5] {
            lanes::check_engine_profile(EngineLaneProfile::Bilateral { m_targets: m }, k)
                .unwrap_or_else(|e| panic!("bilateral K={k} M={m}: {e}"));
        }
    }
    for (n, k) in [(1usize, 1usize), (48, 3), (1024, 16), ((1 << 20), 2)] {
        lanes::check_codec_layout(n, k).unwrap_or_else(|e| panic!("codec n={n} k={k}: {e}"));
    }
    // And the checker actually rejects: shove the rejection uniforms into
    // the draft region.
    let mut broken = lanes::engine_regions(EngineLaneProfile::Rejection, 4);
    broken[1].lo = 0;
    assert!(matches!(
        lanes::check(&broken).unwrap_err(),
        LaneError::Overlap { .. }
    ));
}

/// Satellite property test: the four salted trace sub-RNGs plus the
/// `lane = id` server remap never collide across a 10k-request trace,
/// asserted through the registry (salt distinctness is base-seed
/// independent because `x ^ a == x ^ b` iff `a == b`).
#[test]
fn trace_and_server_lane_conventions_never_collide_over_10k_requests() {
    const N: usize = 10_000;
    lanes::check_trace_salts(N).expect("trace salt collision");

    // Concrete derived seeds for a couple of base seeds, checked whole:
    // 4 stream seeds + 10k prompt seeds pairwise distinct.
    for base in [0u64, 0xD157_1234_5678_9ABC] {
        let mut seeds: Vec<u64> = lanes::TraceStream::ALL
            .iter()
            .map(|&s| lanes::trace_stream_seed(base, s))
            .collect();
        seeds.extend((0..N).map(|i| lanes::trace_prompt_seed(base, i)));
        let total = seeds.len();
        seeds.sort_unstable();
        seeds.dedup();
        assert_eq!(seeds.len(), total, "derived seed collision at base {base:#x}");
    }

    // Server convention: distinct request ids -> distinct split lanes ->
    // distinct per-request RNG key streams.
    let root = CounterRng::new(7);
    let mut keys: Vec<u64> = (0..N as u64)
        .map(|id| root.split(lanes::server_request_lane(id)).lane_key(0, 0))
        .collect();
    let total = keys.len();
    keys.sort_unstable();
    keys.dedup();
    assert_eq!(keys.len(), total, "split-key collision across request ids");
}

/// The scanner's own self-coverage: the analysis module scans itself
/// without self-matching (its pattern strings live in literals, which the
/// stripper removes), and the tree it scanned is non-trivial.
#[test]
fn lint_scan_covers_the_tree_and_does_not_self_match() {
    let files = repo_lint::rust_files(&src_root()).expect("list rust/src");
    assert!(
        files.iter().any(|f| f == "analysis/repo_lint.rs"),
        "scanner must scan itself: {files:?}"
    );
    assert!(files.len() > 20, "suspiciously small tree: {}", files.len());
    let findings = repo_lint::scan_dir(&src_root()).expect("scan rust/src");
    assert!(
        !findings
            .iter()
            .any(|f| f.file.starts_with("analysis/") && f.rule == RuleId::NanUnsafeCmp),
        "lint self-matched its own pattern strings"
    );
}
