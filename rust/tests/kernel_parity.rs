//! Kernel/scalar parity property tests.
//!
//! Determinism is load-bearing for GLS: drafter invariance (paper Def. 1/2)
//! and the coordinator's replay audits both assume verification is a pure
//! function of `(input, randomness)`. The sparse-support workspace kernel
//! (`spec::kernel`) is therefore required to be **bit-exact** with the
//! scalar full-alphabet references (`spec::gls::*_scalar`) — not merely
//! distributionally equivalent. These properties run the two paths on
//! random dense, sparse-support, and top-k-truncated distributions (the
//! paper's LLM regime) and demand identical `GlsOutcome` / `BlockOutput`
//! values.

use gls_serve::spec::daliri::DaliriVerifier;
use gls_serve::spec::gls::{self, GlsVerifier};
use gls_serve::spec::kernel::CouplingWorkspace;
use gls_serve::spec::specinfer::SpecInferVerifier;
use gls_serve::spec::spectr::SpecTrVerifier;
use gls_serve::spec::types::{BlockInput, BlockVerifier, Categorical};
use gls_serve::stats::rng::{CounterRng, XorShift128};
use gls_serve::testkit::{gen_categorical, gen_disjoint_pair, gen_sparse_categorical};

/// Top-k truncated categorical from random logits — the paper's LLM
/// post-processing (top-k 50 at 2048-vocab in the experiments; smaller
/// shapes here to keep the property loops snappy).
fn gen_topk(gen: &mut XorShift128, n: usize, top_k: usize) -> Categorical {
    let logits: Vec<f32> = (0..n).map(|_| (gen.next_f64() * 6.0) as f32).collect();
    Categorical::from_logits(&logits, 1.0, Some(top_k))
}

/// The three distribution regimes every parity property sweeps.
fn gen_by_regime(gen: &mut XorShift128, regime: usize, n: usize) -> Categorical {
    match regime {
        0 => gen_categorical(gen, n),
        1 => gen_sparse_categorical(gen, n, (n / 7).max(2)),
        _ => gen_topk(gen, n, (n / 10).max(2)),
    }
}

#[test]
fn sample_gls_parity_across_regimes() {
    let mut gen = XorShift128::new(0xA11CE);
    let mut ws = CouplingWorkspace::new();
    for case in 0..120u64 {
        let regime = (case % 3) as usize;
        let n = [5usize, 64, 130, 300][(case as usize / 3) % 4];
        let k = [1usize, 2, 4, 8][(case as usize) % 4];
        let p = gen_by_regime(&mut gen, regime, n);
        let q = gen_by_regime(&mut gen, regime, n);
        let rng = CounterRng::new(1000 + case);
        let scalar = gls::sample_gls_scalar(&p, &q, k, &rng, case);
        // Public entry point (thread-local workspace) and an explicit
        // reused workspace must both match the scalar reference exactly.
        assert_eq!(gls::sample_gls(&p, &q, k, &rng, case), scalar, "case {case}");
        assert_eq!(ws.sample_gls(&p, &q, k, &rng, case), scalar, "case {case} (reused ws)");
    }
}

#[test]
fn sample_gls_diverse_parity() {
    let mut gen = XorShift128::new(0xD1CE);
    let mut ws = CouplingWorkspace::new();
    for case in 0..60u64 {
        let regime = (case % 3) as usize;
        let n = [9usize, 80, 200][(case as usize) % 3];
        let k = 1 + (case as usize % 5);
        let ps: Vec<Categorical> =
            (0..k).map(|_| gen_by_regime(&mut gen, regime, n)).collect();
        let q = gen_by_regime(&mut gen, regime, n);
        let rng = CounterRng::new(77 + case);
        let scalar = gls::sample_gls_diverse_scalar(&ps, &q, &rng, case);
        assert_eq!(gls::sample_gls_diverse(&ps, &q, &rng, case), scalar, "case {case}");
        assert_eq!(ws.sample_gls_diverse(&ps, &q, &rng, case), scalar, "case {case}");
    }
}

#[test]
fn sample_gls_bilateral_parity() {
    let mut gen = XorShift128::new(0xB11A);
    let mut ws = CouplingWorkspace::new();
    for case in 0..60u64 {
        let regime = (case % 3) as usize;
        let n = [6usize, 70, 150][(case as usize) % 3];
        let ka = 1 + (case as usize % 4);
        let kb = 1 + ((case as usize / 4) % 3);
        let p = gen_by_regime(&mut gen, regime, n);
        let q = gen_by_regime(&mut gen, regime, n);
        let rng = CounterRng::new(31 + case);
        let scalar = gls::sample_gls_bilateral_scalar(&p, &q, ka, kb, &rng, case);
        assert_eq!(gls::sample_gls_bilateral(&p, &q, ka, kb, &rng, case), scalar, "case {case}");
        assert_eq!(ws.sample_gls_bilateral(&p, &q, ka, kb, &rng, case), scalar, "case {case}");
    }
}

#[test]
fn select_target_token_parity_with_random_active_sets() {
    let mut gen = XorShift128::new(0x5E1);
    let mut ws = CouplingWorkspace::new();
    for case in 0..80u64 {
        let regime = (case % 3) as usize;
        let n = [7usize, 90, 260][(case as usize) % 3];
        let k = 1 + (case as usize % 6);
        let dists: Vec<Categorical> =
            (0..k).map(|_| gen_by_regime(&mut gen, regime, n)).collect();
        let refs: Vec<&Categorical> = dists.iter().collect();
        // Random non-empty ascending active subset (Alg. 2's S after
        // arbitrary divergence patterns).
        let mut active: Vec<usize> =
            (0..k).filter(|_| gen.next_below(2) == 1).collect();
        if active.is_empty() {
            active.push(gen.next_below(k as u64) as usize);
        }
        let rng = CounterRng::new(5000 + case);
        let scalar = gls::select_target_token_scalar(&refs, &active, &rng, case);
        assert_eq!(gls::select_target_token(&refs, &active, &rng, case), scalar, "case {case}");
        assert_eq!(ws.select_target_token(&refs, &active, &rng, case), scalar, "case {case}");
    }
}

fn random_block(gen: &mut XorShift128, regime: usize, k: usize, l: usize, n: usize, seed: u64) -> BlockInput {
    let p: Vec<Categorical> = (0..l).map(|_| gen_by_regime(gen, regime, n)).collect();
    let rng = CounterRng::new(seed ^ 0xDEAD);
    let mut draft_tokens = vec![Vec::with_capacity(l); k];
    for kk in 0..k {
        for j in 0..l {
            draft_tokens[kk].push(p[j].sample_race(&rng, j as u64, kk as u64) as u32);
        }
    }
    let shared_q: Vec<Categorical> = (0..=l).map(|_| gen_by_regime(gen, regime, n)).collect();
    BlockInput {
        draft_dists: vec![p; k],
        target_dists: vec![shared_q; k],
        draft_tokens: draft_tokens.into(),
    }
}

#[test]
fn verify_block_parity_conditional_and_strong() {
    let mut gen = XorShift128::new(0xB10C);
    for case in 0..60u64 {
        let regime = (case % 3) as usize;
        let n = [6usize, 64, 300][(case as usize) % 3];
        let k = 1 + (case as usize % 5);
        let l = 1 + (case as usize % 4);
        let input = random_block(&mut gen, regime, k, l, n, case);
        let rng = CounterRng::new(case * 31 + 7);
        for v in [GlsVerifier::conditional(), GlsVerifier::strong()] {
            let scalar = v.verify_block_scalar(&input, &rng, case);
            let kernel = v.verify_block(&input, &rng, case);
            assert_eq!(kernel, scalar, "case {case} strong-variant mismatch");
        }
    }
}

#[test]
fn verify_block_parity_llm_regime_k8_topk50() {
    // The acceptance-criterion shape: K=8, N=2048, top-k-50 target
    // distributions — exactly what benches/perf_engine.rs times.
    let mut gen = XorShift128::new(0x2048);
    let k = 8;
    let l = 4;
    let n = 2048;
    for case in 0..6u64 {
        let p: Vec<Categorical> = (0..l).map(|_| gen_topk(&mut gen, n, 50)).collect();
        let rng_draft = CounterRng::new(case ^ 0xFACE);
        let mut draft_tokens = vec![Vec::with_capacity(l); k];
        for kk in 0..k {
            for j in 0..l {
                draft_tokens[kk].push(p[j].sample_race(&rng_draft, j as u64, kk as u64) as u32);
            }
        }
        let q: Vec<Categorical> = (0..=l).map(|_| gen_topk(&mut gen, n, 50)).collect();
        let input = BlockInput {
            draft_dists: vec![p; k],
            target_dists: vec![q; k],
            draft_tokens: draft_tokens.into(),
        };
        let rng = CounterRng::new(900 + case);
        let v = GlsVerifier::conditional();
        assert_eq!(v.verify_block(&input, &rng, case * 10), v.verify_block_scalar(&input, &rng, case * 10));
    }
}

/// Number of draft/target regimes [`random_block_ext`] sweeps. Regimes 0–2
/// are the standard dense / sparse / top-k shapes; 3–5 are the degenerate
/// supports the per-verifier parity suites must cover: point-mass drafts,
/// disjoint draft/target supports, and `top_k ≥ vocab` (no truncation, no
/// cached support).
const EXT_REGIMES: usize = 6;

/// Regime-indexed `(p, q)` generator extending [`gen_by_regime`] with the
/// degenerate shapes.
fn gen_pq_ext(gen: &mut XorShift128, regime: usize, n: usize) -> (Categorical, Categorical) {
    match regime {
        3 => (
            Categorical::delta(n, gen.next_below(n as u64) as usize),
            gen_categorical(gen, n),
        ),
        4 => gen_disjoint_pair(gen, n),
        5 => {
            let mut topk_ge_vocab = |extra: usize| {
                let logits: Vec<f32> =
                    (0..n).map(|_| (gen.next_f64() * 6.0) as f32).collect();
                Categorical::from_logits(&logits, 1.0, Some(n + extra))
            };
            (topk_ge_vocab(0), topk_ge_vocab(3))
        }
        r => (gen_by_regime(gen, r, n), gen_by_regime(gen, r, n)),
    }
}

/// BlockInput over the extended regimes. Draft distributions are identical
/// across lanes (the i.i.d. shape SpecTr requires; GLS/SpecInfer/Daliri
/// accept it too) and draft tokens come from the coupled race at the same
/// `(slot, lane)` coordinates the engine would use.
fn random_block_ext(
    gen: &mut XorShift128,
    regime: usize,
    k: usize,
    l: usize,
    n: usize,
    seed: u64,
) -> BlockInput {
    let mut ps = Vec::with_capacity(l);
    let mut qs = Vec::with_capacity(l + 1);
    for _ in 0..l {
        let (p, q) = gen_pq_ext(gen, regime, n);
        ps.push(p);
        qs.push(q);
    }
    let (_, q_bonus) = gen_pq_ext(gen, regime, n);
    qs.push(q_bonus);
    let rng = CounterRng::new(seed ^ 0xDEAD);
    let mut draft_tokens = vec![Vec::with_capacity(l); k];
    for kk in 0..k {
        for j in 0..l {
            draft_tokens[kk].push(ps[j].sample_race(&rng, j as u64, kk as u64) as u32);
        }
    }
    BlockInput {
        draft_dists: vec![ps; k],
        target_dists: vec![qs; k],
        draft_tokens: draft_tokens.into(),
    }
}

#[test]
fn spectr_verify_block_parity() {
    let mut gen = XorShift128::new(0x57EC);
    let mut ws = CouplingWorkspace::new();
    let v = SpecTrVerifier::new();
    for case in 0..90u64 {
        let regime = (case as usize) % EXT_REGIMES;
        let n = [6usize, 64, 300][(case as usize / EXT_REGIMES) % 3];
        let k = 1 + (case as usize % 5);
        let l = 1 + (case as usize % 4);
        let input = random_block_ext(&mut gen, regime, k, l, n, case);
        let rng = CounterRng::new(0x7000 + case);
        let scalar = v.verify_block_scalar(&input, &rng, case);
        assert_eq!(v.verify_block(&input, &rng, case), scalar, "case {case} regime {regime}");
        assert_eq!(
            ws.verify_block_spectr(&input, &rng, case),
            scalar,
            "case {case} regime {regime} (reused ws)"
        );
    }
}

#[test]
fn specinfer_verify_block_parity() {
    let mut gen = XorShift128::new(0x51F3);
    let mut ws = CouplingWorkspace::new();
    let v = SpecInferVerifier::new();
    for case in 0..90u64 {
        let regime = (case as usize) % EXT_REGIMES;
        let n = [5usize, 80, 260][(case as usize / EXT_REGIMES) % 3];
        let k = 1 + (case as usize % 5);
        let l = 1 + (case as usize % 4);
        let input = random_block_ext(&mut gen, regime, k, l, n, case);
        let rng = CounterRng::new(0x8000 + case);
        let scalar = v.verify_block_scalar(&input, &rng, case);
        assert_eq!(v.verify_block(&input, &rng, case), scalar, "case {case} regime {regime}");
        assert_eq!(
            ws.verify_block_specinfer(&input, &rng, case),
            scalar,
            "case {case} regime {regime} (reused ws)"
        );
    }
}

#[test]
fn daliri_verify_block_parity() {
    let mut gen = XorShift128::new(0xDA11);
    let mut ws = CouplingWorkspace::new();
    let v = DaliriVerifier::new();
    for case in 0..90u64 {
        let regime = (case as usize) % EXT_REGIMES;
        let n = [7usize, 70, 320][(case as usize / EXT_REGIMES) % 3];
        let l = 1 + (case as usize % 5);
        // Daliri is single-draft; still build multi-lane inputs sometimes
        // (the verifier must ignore lanes ≥ 1).
        let k = 1 + (case as usize % 3);
        let input = random_block_ext(&mut gen, regime, k, l, n, case);
        let rng = CounterRng::new(0x9000 + case);
        let scalar = v.verify_block_scalar(&input, &rng, case);
        assert_eq!(v.verify_block(&input, &rng, case), scalar, "case {case} regime {regime}");
        assert_eq!(
            ws.verify_block_daliri(&input, &rng, case),
            scalar,
            "case {case} regime {regime} (reused ws)"
        );
    }
}

#[test]
fn ported_verifiers_parity_llm_regime_k8_topk50() {
    // The acceptance-criterion shape for every ported baseline: K=8,
    // N=2048, top-k-50 — exactly what benches/perf_engine.rs times and CI
    // gates at ≥3× per verifier.
    let mut gen = XorShift128::new(0x4821);
    let k = 8;
    let l = 4;
    let n = 2048;
    for case in 0..4u64 {
        let p: Vec<Categorical> = (0..l).map(|_| gen_topk(&mut gen, n, 50)).collect();
        let rng_draft = CounterRng::new(case ^ 0xFACE);
        let mut draft_tokens = vec![Vec::with_capacity(l); k];
        for kk in 0..k {
            for j in 0..l {
                draft_tokens[kk].push(p[j].sample_race(&rng_draft, j as u64, kk as u64) as u32);
            }
        }
        let q: Vec<Categorical> = (0..=l).map(|_| gen_topk(&mut gen, n, 50)).collect();
        let input = BlockInput {
            draft_dists: vec![p; k],
            target_dists: vec![q; k],
            draft_tokens: draft_tokens.into(),
        };
        let rng = CounterRng::new(1700 + case);
        let spectr = SpecTrVerifier::new();
        assert_eq!(
            spectr.verify_block(&input, &rng, case * 10),
            spectr.verify_block_scalar(&input, &rng, case * 10),
            "spectr case {case}"
        );
        let specinfer = SpecInferVerifier::new();
        assert_eq!(
            specinfer.verify_block(&input, &rng, case * 10),
            specinfer.verify_block_scalar(&input, &rng, case * 10),
            "specinfer case {case}"
        );
        let daliri = DaliriVerifier::new();
        assert_eq!(
            daliri.verify_block(&input, &rng, case * 10),
            daliri.verify_block_scalar(&input, &rng, case * 10),
            "daliri case {case}"
        );
    }
}

#[test]
fn panel_slice_record_race_matches_categorical_sample_race() {
    // The engine's draft phase records races into per-sequence panel
    // slices (the cross-thread handoff); recording must be bit-exact with
    // the plain race, and the slice must grow one row per race.
    use gls_serve::spec::PanelSlice;
    let mut gen = XorShift128::new(0xD4A1);
    for case in 0..40u64 {
        let n = [20usize, 150, 2048][(case as usize) % 3];
        let d = match case % 3 {
            0 => gen_categorical(&mut gen, n),
            1 => gen_sparse_categorical(&mut gen, n, (n / 9).max(2)),
            _ => gen_topk(&mut gen, n, (n / 12).max(2)),
        };
        let rng = CounterRng::new(2200 + case);
        let mut slice = PanelSlice::new();
        for lane in 0..4u64 {
            assert_eq!(
                slice.record_race(&d, &rng, case, lane),
                d.sample_race(&rng, case, lane),
                "case {case} lane {lane}"
            );
        }
        assert_eq!(slice.len(), 4);
    }
}

#[test]
fn sample_race_support_cache_is_exact() {
    // sample_race over a cached top-k support must match the dense scan on
    // the identical probability vector (cache stripped via Categorical::new).
    let mut gen = XorShift128::new(0x5A7E);
    for case in 0..40u64 {
        let n = [60usize, 300, 2048][(case as usize) % 3];
        let c = gen_topk(&mut gen, n, (n / 12).max(2));
        assert!(c.support().is_some());
        let dense = Categorical::new(c.probs().to_vec());
        assert!(dense.support().is_none());
        let rng = CounterRng::new(400 + case);
        for draft in 0..3u64 {
            assert_eq!(
                c.sample_race(&rng, case, draft),
                dense.sample_race(&rng, case, draft),
                "case {case} draft {draft}"
            );
        }
    }
}

#[test]
fn forced_slot_collisions_stay_bit_exact_for_every_verifier() {
    // The leaky panel cache is direct-mapped into PANEL_CACHE_SLOTS slots,
    // so racing several times more distinct (slot, lane) lane keys than
    // slots forces collision overwrites by pigeonhole — whatever SplitMix64
    // does to the keys. A second pass then revisits every block, probing
    // slots whose occupants were overwritten in between. None of it may
    // change a token for ANY registered verifier: reuse is an optimization,
    // recompute-on-miss is the fallback, and the scalar references are the
    // oracle. One workspace persists across all kinds and both passes for
    // maximal cross-pollution of the cache.
    use gls_serve::spec::all_verifiers;
    use gls_serve::spec::kernel::{PanelCacheStats, PANEL_CACHE_SLOTS};
    use gls_serve::spec::single_draft::SingleDraftVerifier;
    use gls_serve::spec::types::{BlockOutput, VerifierKind};

    let scalar_reference =
        |kind: VerifierKind, input: &BlockInput, rng: &CounterRng, slot0: u64| -> BlockOutput {
            match kind {
                VerifierKind::Gls => GlsVerifier::conditional().verify_block_scalar(input, rng, slot0),
                VerifierKind::GlsStrong => GlsVerifier::strong().verify_block_scalar(input, rng, slot0),
                VerifierKind::SpecTr => SpecTrVerifier::new().verify_block_scalar(input, rng, slot0),
                VerifierKind::SpecInfer => {
                    SpecInferVerifier::new().verify_block_scalar(input, rng, slot0)
                }
                VerifierKind::SingleDraft => {
                    SingleDraftVerifier::new().verify_block_scalar(input, rng, slot0)
                }
                VerifierKind::Daliri => DaliriVerifier::new().verify_block_scalar(input, rng, slot0),
                other => unreachable!("no scalar reference for {other:?}"),
            }
        };

    let (k, l, n) = (4usize, 3usize, 257usize);
    // Each block's verification keys k lanes at each of l+1 slots; size the
    // sweep so the keyed lanes outnumber the direct-mapped slots ~3×.
    let n_blocks = (3 * PANEL_CACHE_SLOTS) / (k * (l + 1)) + 1;
    let mut ws = CouplingWorkspace::new();
    let mut stats = PanelCacheStats::default();
    let mut gen = XorShift128::new(0xC011);
    for v in all_verifiers() {
        let kind = v.kind();
        // Same rng and slots for every kind: each kind probes slots the
        // previous kind populated (same lane keys, different visit
        // patterns) — legal reuse under the key-purity contract.
        let rng = CounterRng::new(0xBEEF);
        let blocks: Vec<(u64, BlockInput)> = (0..n_blocks)
            .map(|b| {
                let slot0 = (b * (l + 1)) as u64;
                (slot0, random_block(&mut gen, b % 3, k, l, n, 0x9000 + b as u64))
            })
            .collect();
        for pass in 0..2 {
            for (slot0, input) in &blocks {
                let out = ws.verify_block_kind(kind, input, &rng, *slot0);
                let reference = scalar_reference(kind, input, &rng, *slot0);
                assert_eq!(out, reference, "{kind:?} pass {pass} slot0 {slot0}");
            }
        }
        stats.merge(ws.drain_cache_stats());
    }
    assert!(stats.misses > 0, "cold probes never missed — counters broken");
    assert!(
        stats.overwrites > 0,
        "flooding {PANEL_CACHE_SLOTS} slots with {n_blocks} blocks/kind never collided"
    );
    assert!(stats.hits > 0, "revisit passes never hit a surviving row");
}

#[test]
fn from_logits_scratch_reuse_is_exact() {
    let mut gen = XorShift128::new(0x70F);
    let mut scratch = Vec::new();
    for case in 0..40 {
        let n = [3usize, 50, 333, 2048][case % 4];
        let logits: Vec<f32> = (0..n).map(|_| (gen.next_f64() * 9.0 - 4.0) as f32).collect();
        let top_k = match case % 3 {
            0 => None,
            1 => Some(1),
            _ => Some((n / 8).max(2)),
        };
        let temp = 0.25 + gen.next_f64() * 3.0;
        let fresh = Categorical::from_logits(&logits, temp, top_k);
        let reused = Categorical::from_logits_with_scratch(&logits, temp, top_k, &mut scratch);
        assert_eq!(fresh, reused, "case {case} (n={n}, top_k={top_k:?})");
    }
}

#[test]
fn exponential_matrix_flat_layout_matches_coordinates() {
    let rng = CounterRng::new(0xE4);
    let (drafts, items) = (5usize, 37usize);
    let m = rng.exponential_matrix(9, drafts, items);
    assert_eq!(m.len(), drafts * items);
    for k in 0..drafts as u64 {
        for i in 0..items as u64 {
            assert_eq!(m[(k as usize) * items + i as usize], rng.exponential(9, k, i));
        }
    }
}

#[test]
fn engine_parallel_batch_matches_sequential_stepping() {
    // The parallel verification path (large vocab, batch ≥ 2) must emit
    // exactly what per-sequence stepping emits, for every kernel-backed
    // verifier kind: verification is a pure function of the per-sequence
    // randomness lane, and the panel slices handed from the draft phase to
    // the pool workers must not change a single token.
    use gls_serve::coordinator::engine::SpecDecodeEngine;
    use gls_serve::coordinator::kv::PagedKvCache;
    use gls_serve::coordinator::sequence::{Request, SequenceState};
    use gls_serve::coordinator::EngineConfig;
    use gls_serve::model::backend::ModelPair;
    use gls_serve::model::sampling::SamplingParams;
    use gls_serve::model::sim::SimLm;
    use gls_serve::spec::types::VerifierKind;

    let vocab = 600; // k·(l+1)·vocab clears the parallel-dispatch threshold
    for &vk in &[
        VerifierKind::Gls,
        VerifierKind::SpecTr,
        VerifierKind::SpecInfer,
        VerifierKind::Daliri,
    ] {
        let mk_engine = || {
            let (d, t) = SimLm::pair(vocab, 21, 2.0);
            let cfg = EngineConfig {
                num_drafts: 8,
                block_len: 4,
                verifier: vk,
                target_params: SamplingParams::new(1.0, Some(50)),
                draft_params: vec![SamplingParams::new(1.0, Some(50))],
                max_seq_len: 256,
                seed: 99,
                ..EngineConfig::default()
            };
            SpecDecodeEngine::new(
                cfg,
                ModelPair::new(Box::new(d), Box::new(t)),
                PagedKvCache::new(4096, 16),
            )
        };
        let n_seqs = 12u64;
        let mk_seqs = || -> Vec<SequenceState> {
            (0..n_seqs)
                .map(|i| {
                    SequenceState::from_request(&Request::new(i, vec![1, 2, (i % 9) as u32], 10))
                })
                .collect()
        };

        let mut eng_batch = mk_engine();
        let mut batch_seqs = mk_seqs();
        for s in &batch_seqs {
            eng_batch.kv.register(s.id, s.tokens.len(), s.tokens.len() + 15, 5).unwrap();
        }
        {
            let mut refs: Vec<&mut SequenceState> = batch_seqs.iter_mut().collect();
            eng_batch.step_blocks(&mut refs);
        }

        let mut eng_seq = mk_engine();
        let mut solo_seqs = mk_seqs();
        for s in &solo_seqs {
            eng_seq.kv.register(s.id, s.tokens.len(), s.tokens.len() + 15, 5).unwrap();
        }
        for s in solo_seqs.iter_mut() {
            let mut one = [s];
            eng_seq.step_blocks(&mut one);
        }

        for (a, b) in batch_seqs.iter().zip(&solo_seqs) {
            assert_eq!(a.tokens, b.tokens, "seq {} diverged under batching ({vk:?})", a.id);
        }
    }
}

/// Single-draft TR baseline: kernel residual path vs the scalar reference,
/// across the extended regimes (incl. point mass / disjoint / top_k ≥
/// vocab) — the last verifier ported onto `ResidualScratch`.
#[test]
fn single_draft_verify_block_parity() {
    use gls_serve::spec::single_draft::SingleDraftVerifier;
    let mut gen = XorShift128::new(0x51D7);
    let mut ws = CouplingWorkspace::new();
    let v = SingleDraftVerifier::new();
    for case in 0..90u64 {
        let regime = (case as usize) % EXT_REGIMES;
        let n = [5usize, 60, 280][(case as usize / EXT_REGIMES) % 3];
        let l = 1 + (case as usize % 5);
        // Single-draft ignores extra lanes; still build a few sometimes.
        let k = 1 + (case as usize % 2);
        let input = random_block_ext(&mut gen, regime, k, l, n, case);
        let rng = CounterRng::new(0xA000 + case);
        let scalar = v.verify_block_scalar(&input, &rng, case);
        assert_eq!(v.verify_block(&input, &rng, case), scalar, "case {case} regime {regime}");
        assert_eq!(
            ws.verify_block_single_draft(&input, &rng, case),
            scalar,
            "case {case} regime {regime} (reused ws)"
        );
    }
}

// ---------------------------------------------------------------------------
// Pool-vs-serial engine grid (the persistent-worker-pool acceptance bar).
// ---------------------------------------------------------------------------

mod pool_grid {
    use gls_serve::coordinator::config::VerifyBackend;
    use gls_serve::coordinator::engine::SpecDecodeEngine;
    use gls_serve::coordinator::kv::PagedKvCache;
    use gls_serve::coordinator::sequence::{Request, SequenceState};
    use gls_serve::coordinator::EngineConfig;
    use gls_serve::model::backend::ModelPair;
    use gls_serve::model::sampling::SamplingParams;
    use gls_serve::model::sim::SimLm;
    use gls_serve::spec::types::VerifierKind;

    /// One adversarial engine shape for the grid.
    struct Shape {
        label: &'static str,
        vocab: usize,
        top_k: Option<usize>,
        n_seqs: u64,
        /// Work threshold: 0 forces fan-out even below the calibrated
        /// default; `usize::MAX` would force serial (covered by the
        /// Serial-backend oracle itself).
        parallel_threshold: usize,
    }

    const SHAPES: &[Shape] = &[
        // Single-sequence batch: must never fan out, must still match.
        Shape { label: "single-seq", vocab: 600, top_k: Some(50), n_seqs: 1, parallel_threshold: 0 },
        // Below the calibrated threshold but fan-out forced.
        Shape { label: "below-threshold", vocab: 40, top_k: Some(13), n_seqs: 6, parallel_threshold: 0 },
        // Above the calibrated threshold (natural dispatch decision).
        Shape { label: "above-threshold", vocab: 600, top_k: Some(50), n_seqs: 9, parallel_threshold: 8192 },
        // Point-mass targets (top-k 1): exact deltas through the races.
        Shape { label: "point-mass", vocab: 600, top_k: Some(1), n_seqs: 6, parallel_threshold: 0 },
    ];

    fn build(
        vk: VerifierKind,
        shape: &Shape,
        backend: VerifyBackend,
        workers: usize,
    ) -> SpecDecodeEngine {
        let (d, t) = SimLm::pair(shape.vocab, 23, 2.0);
        let cfg = EngineConfig {
            num_drafts: 4,
            block_len: 3,
            verifier: vk,
            target_params: SamplingParams::new(1.0, shape.top_k),
            draft_params: vec![SamplingParams::new(1.0, shape.top_k)],
            max_seq_len: 256,
            seed: 31,
            parallel_threshold: shape.parallel_threshold,
            verify_workers: workers,
            verify_backend: backend,
            ..EngineConfig::default()
        };
        SpecDecodeEngine::new(
            cfg,
            ModelPair::new(Box::new(d), Box::new(t)),
            PagedKvCache::new(8192, 16),
        )
    }

    fn run(vk: VerifierKind, shape: &Shape, backend: VerifyBackend, workers: usize) -> Vec<Vec<u32>> {
        let mut eng = build(vk, shape, backend, workers);
        let mut seqs: Vec<SequenceState> = (0..shape.n_seqs)
            .map(|i| SequenceState::from_request(&Request::new(i, vec![1, (i % 5) as u32], 9)))
            .collect();
        for s in &seqs {
            eng.kv.register(s.id, s.tokens.len(), s.tokens.len() + 14, 4).unwrap();
        }
        // Two rounds so pool workspaces (and their caches) carry state
        // across blocks, like production steady state.
        for _ in 0..2 {
            let mut refs: Vec<&mut SequenceState> = seqs.iter_mut().collect();
            eng.step_blocks(&mut refs);
        }
        seqs.into_iter().map(|s| s.tokens).collect()
    }

    /// Pool sizes {1, 2, 4} × adversarial shapes × every registered
    /// verifier: the pooled engine must be bit-exact with the serial
    /// oracle everywhere. (The scoped-spawn baseline is covered at one
    /// pool size to keep the grid affordable — it shares the job/run code
    /// with the pool, differing only in thread lifecycle.)
    #[test]
    fn pool_is_bit_exact_with_serial_for_every_verifier() {
        for &vk in VerifierKind::all() {
            for shape in SHAPES {
                let serial = run(vk, shape, VerifyBackend::Serial, 0);
                for &workers in &[1usize, 2, 4] {
                    let pooled = run(vk, shape, VerifyBackend::Pool, workers);
                    assert_eq!(
                        pooled, serial,
                        "{vk:?} / {} / pool({workers}) diverged from serial",
                        shape.label
                    );
                }
                let spawned = run(vk, shape, VerifyBackend::Spawn, 2);
                assert_eq!(
                    spawned, serial,
                    "{vk:?} / {} / spawn diverged from serial",
                    shape.label
                );
            }
        }
    }

    /// Server-global pool extension of the grid: several engines sharing
    /// ONE `VerifyPool` (the `pool_scope = server` topology), each stepped
    /// across blocks, must every one be bit-exact with its own serial
    /// twin — ticket isolation means sharing can never mix or alter
    /// outcomes, for every registered verifier.
    #[test]
    fn shared_pool_across_engines_is_bit_exact_with_serial() {
        use gls_serve::coordinator::VerifyPool;
        use std::sync::Arc;
        for &vk in VerifierKind::all() {
            let shape = &SHAPES[1]; // multi-seq, fan-out forced
            let pool = Arc::new(VerifyPool::new(2));
            let n_engines = 3usize;
            let mut shared_out: Vec<Vec<Vec<u32>>> = Vec::new();
            for e in 0..n_engines {
                // Distinct seeds per engine (the config seed is fixed, so
                // vary the request ids → randomness lanes).
                let mut eng = build(vk, shape, VerifyBackend::Pool, 2);
                eng.attach_shared_pool(Arc::clone(&pool), e as u64);
                let mut seqs: Vec<SequenceState> = (0..shape.n_seqs)
                    .map(|i| {
                        let id = e as u64 * 100 + i;
                        SequenceState::from_request(&Request::new(id, vec![1, (i % 5) as u32], 9))
                    })
                    .collect();
                for s in &seqs {
                    eng.kv.register(s.id, s.tokens.len(), s.tokens.len() + 14, 4).unwrap();
                }
                for _ in 0..2 {
                    let mut refs: Vec<&mut SequenceState> = seqs.iter_mut().collect();
                    eng.step_blocks(&mut refs);
                }
                shared_out.push(seqs.into_iter().map(|s| s.tokens).collect());
            }
            for (e, shared) in shared_out.iter().enumerate() {
                let mut eng = build(vk, shape, VerifyBackend::Serial, 0);
                let mut seqs: Vec<SequenceState> = (0..shape.n_seqs)
                    .map(|i| {
                        let id = e as u64 * 100 + i;
                        SequenceState::from_request(&Request::new(id, vec![1, (i % 5) as u32], 9))
                    })
                    .collect();
                for s in &seqs {
                    eng.kv.register(s.id, s.tokens.len(), s.tokens.len() + 14, 4).unwrap();
                }
                for _ in 0..2 {
                    let mut refs: Vec<&mut SequenceState> = seqs.iter_mut().collect();
                    eng.step_blocks(&mut refs);
                }
                let serial: Vec<Vec<u32>> = seqs.into_iter().map(|s| s.tokens).collect();
                assert_eq!(
                    *shared, serial,
                    "{vk:?}: engine {e} diverged on the shared pool"
                );
            }
            // Every engine's submissions were attributed to its own tag.
            for e in 0..n_engines {
                assert!(pool.engine_stats(e as u64).jobs > 0, "{vk:?}: engine {e} untracked");
            }
        }
    }

    /// Cache-handoff acceptance: worker-verified panels must match
    /// serially-verified ones AND the pooled engine must report draft-phase
    /// panel reuse actually firing on its workers (the counter the
    /// `PanelSlice` protocol exists for).
    #[test]
    fn pool_handoff_matches_serial_and_hits() {
        for &vk in &[VerifierKind::Gls, VerifierKind::GlsStrong, VerifierKind::Daliri] {
            let shape = &SHAPES[2]; // above-threshold, the production shape
            let mut serial_eng = build(vk, shape, VerifyBackend::Serial, 0);
            let mut pooled_eng = build(vk, shape, VerifyBackend::Pool, 2);
            let mk = || -> Vec<SequenceState> {
                (0..shape.n_seqs)
                    .map(|i| SequenceState::from_request(&Request::new(i, vec![2, (i % 3) as u32], 9)))
                    .collect()
            };
            let mut ss = mk();
            let mut ps = mk();
            for s in &ss {
                serial_eng.kv.register(s.id, s.tokens.len(), s.tokens.len() + 14, 4).unwrap();
            }
            for s in &ps {
                pooled_eng.kv.register(s.id, s.tokens.len(), s.tokens.len() + 14, 4).unwrap();
            }
            for _ in 0..2 {
                let mut refs: Vec<&mut SequenceState> = ss.iter_mut().collect();
                serial_eng.step_blocks(&mut refs);
                let mut refs: Vec<&mut SequenceState> = ps.iter_mut().collect();
                pooled_eng.step_blocks(&mut refs);
            }
            for (a, b) in ps.iter().zip(&ss) {
                assert_eq!(a.tokens, b.tokens, "{vk:?}: worker-verified panel diverged");
            }
            assert!(
                pooled_eng.metrics.panel_cache_hits > 0,
                "{vk:?}: draft-phase panel reuse never fired on pool workers"
            );
            assert!(
                serial_eng.metrics.panel_cache_hits > 0,
                "{vk:?}: draft-phase panel reuse never fired serially"
            );
            // The miss side of the ledger flows back through both paths
            // too: cold probes (e.g. the bonus position, which has no
            // recorded draft panel) must surface as misses — i.e. the
            // counters are wired, not defaulted. (Overwrite counting is
            // pinned by the forced-collision property above and the
            // kernel's own unit suite.)
            assert!(
                pooled_eng.metrics.panel_cache_misses > 0,
                "{vk:?}: pool workers reported no cold-probe misses"
            );
            assert!(
                serial_eng.metrics.panel_cache_misses > 0,
                "{vk:?}: serial path reported no cold-probe misses"
            );
        }
    }
}
