//! Integration tests over the AOT artifacts: the full L1→L2→L3 composition.
//!
//! These tests are skipped (with a notice) when `artifacts/` is missing —
//! run `make artifacts` first. Everything else in the suite runs without
//! artifacts. The whole file is compiled only with the `pjrt` feature (the
//! PJRT bridge needs the vendored `xla` crate; see Cargo.toml).
#![cfg(feature = "pjrt")]

use gls_serve::compression::image::{left_crop, right_half, synthetic_digits, LatentCodecModel};
use gls_serve::coordinator::engine::SpecDecodeEngine;
use gls_serve::coordinator::kv::PagedKvCache;
use gls_serve::coordinator::sequence::{Request, SequenceState};
use gls_serve::coordinator::EngineConfig;
use gls_serve::model::backend::{LmBackend, ModelPair};
use gls_serve::model::sampling::SamplingParams;
use gls_serve::model::tokenizer::ByteTokenizer;
use gls_serve::runtime::{ArtifactManifest, PjrtLm, PjrtVae};
use gls_serve::spec::types::VerifierKind;

fn manifest() -> Option<ArtifactManifest> {
    match gls_serve::runtime::Artifacts::discover() {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("SKIP (no artifacts): {e}");
            None
        }
    }
}

#[test]
fn pjrt_lm_loads_and_produces_finite_logits() {
    let Some(m) = manifest() else { return };
    let mut lm = PjrtLm::load(&m, "target_lm").expect("load target_lm");
    assert_eq!(lm.vocab(), 259);
    let tok = ByteTokenizer::new();
    let seqs = vec![tok.encode("ada buys 3 apples"), tok.encode("def sum")];
    let logits = lm.next_logits(&seqs);
    assert_eq!(logits.len(), 2);
    assert_eq!(logits[0].len(), 259);
    assert!(logits.iter().flatten().all(|x| x.is_finite()));
    // The trained model should be context-sensitive.
    assert_ne!(logits[0], logits[1]);
}

#[test]
fn pjrt_lm_span_consistent_with_next() {
    let Some(m) = manifest() else { return };
    let mut lm = PjrtLm::load(&m, "draft_lm").expect("load draft_lm");
    let tok = ByteTokenizer::new();
    let seq = tok.encode("cleo counts 7 coins");
    let span = lm.span_logits(&[seq.clone()], seq.len() - 2);
    // Span covers prefix lengths len-3 ..= len: 4 positions.
    assert_eq!(span[0].len(), 4);
    let next = lm.next_logits(&[seq.clone()]);
    // Last span position == next-token logits for the full sequence.
    for (a, b) in span[0].last().unwrap().iter().zip(&next[0]) {
        assert!((a - b).abs() < 1e-4, "span/next disagree: {a} vs {b}");
    }
}

#[test]
fn trained_draft_is_aligned_with_target() {
    // The whole premise of speculative decoding: the draft's next-token
    // distribution is close to the target's on in-distribution text.
    let Some(m) = manifest() else { return };
    let mut draft = PjrtLm::load(&m, "draft_lm").unwrap();
    let mut target = PjrtLm::load(&m, "target_lm").unwrap();
    let tok = ByteTokenizer::new();
    let prompts = ["bob sells 12 eggs and then 5 more. total:", "def min3(xs): return "];
    let mut tv_total = 0.0;
    for p in prompts {
        let seq = tok.encode(p);
        let dq = gls_serve::spec::types::Categorical::from_logits(
            &draft.next_logits(&[seq.clone()])[0],
            1.0,
            None,
        );
        let tq = gls_serve::spec::types::Categorical::from_logits(
            &target.next_logits(&[seq])[0],
            1.0,
            None,
        );
        tv_total += dq.tv_distance(&tq);
    }
    let mean_tv = tv_total / prompts.len() as f64;
    assert!(mean_tv < 0.8, "draft/target hopelessly misaligned: TV {mean_tv}");
}

#[test]
fn engine_decodes_through_pjrt_backends() {
    // Full-stack smoke: coordinator → PJRT artifacts → Pallas-bearing HLO.
    let Some(m) = manifest() else { return };
    let draft = PjrtLm::load(&m, "draft_lm").unwrap();
    let target = PjrtLm::load(&m, "target_lm").unwrap();
    let cfg = EngineConfig {
        num_drafts: 2,
        block_len: 3,
        verifier: VerifierKind::Gls,
        target_params: SamplingParams::new(1.0, Some(50)),
        draft_params: vec![SamplingParams::new(1.0, Some(50))],
        max_seq_len: 96,
        seed: 7,
        ..EngineConfig::default()
    };
    let mut eng = SpecDecodeEngine::new(
        cfg,
        ModelPair::new(Box::new(draft), Box::new(target)),
        PagedKvCache::new(256, 16),
    );
    let tok = ByteTokenizer::new();
    let req = Request::new(1, tok.encode("ada buys 3 apples and then 4 more. total:"), 12);
    let mut seq = SequenceState::from_request(&req);
    eng.decode_sequence(&mut seq);
    assert_eq!(seq.generated(), 12);
    assert!(seq.block_efficiency() > 1.0, "BE {}", seq.block_efficiency());
    let text = tok.decode(&seq.tokens);
    assert!(!text.is_empty());
    eprintln!("pjrt decode: BE={:.2} text={text:?}", seq.block_efficiency());
}

#[test]
fn pjrt_vae_roundtrips() {
    let Some(m) = manifest() else { return };
    let vae = PjrtVae::load(&m).expect("load vae");
    assert_eq!(vae.latent_dim(), 4);
    let imgs = synthetic_digits(3, 77);
    let src = right_half(&imgs[0]);
    let (mu, var) = vae.encode(&src);
    assert_eq!(mu.len(), 4);
    assert!(var.iter().all(|&v| v > 0.0));
    let feat = vae.project(&left_crop(&imgs[0], 3, 10));
    assert_eq!(feat.len(), 32);
    let lr = vae.estimate_logratio(&mu, &feat);
    assert!(lr.is_finite());
    let recon = vae.decode(&mu, &feat);
    assert_eq!(recon.len(), 392);
    assert!(recon.iter().all(|&p| (0.0..=1.0).contains(&p)));
    // Smoke-level sanity on the estimator (the *statistical*
    // discriminativeness assertion lives in python/tests/test_vae_stats.py,
    // where evaluating hundreds of pairs is cheap): outputs are finite and
    // vary with the side features.
    let (mu0, _) = vae.encode(&right_half(&imgs[0]));
    let fa = vae.project(&left_crop(&imgs[0], 0, 0));
    let fb = vae.project(&left_crop(&imgs[1], 7, 21));
    let la = vae.estimate_logratio(&mu0, &fa);
    let lb = vae.estimate_logratio(&mu0, &fb);
    assert!(la.is_finite() && lb.is_finite());
    assert_ne!(la, lb, "estimator ignores side features");
}

#[test]
fn gls_select_artifact_matches_native_rust() {
    // The L1 kernel through the full AOT path agrees with the Rust-native
    // implementation given identical uniforms — the cross-layer contract.
    let Some(m) = manifest() else { return };
    use gls_serve::runtime::client::{compile_hlo_file, execute_tuple, new_client};
    let client = new_client().unwrap();
    let exe = compile_hlo_file(&client, &m.path("gls_select").unwrap()).unwrap();
    let k = m.get_usize("gls_k").unwrap();
    let n = m.get_usize("gls_n").unwrap();

    use gls_serve::stats::rng::CounterRng;
    let rng = CounterRng::new(42);
    for trial in 0..5u64 {
        // Build u, q, p on the Rust side.
        let mut u = vec![0f32; k * n];
        for kk in 0..k {
            for i in 0..n {
                u[kk * n + i] = rng.uniform(trial, kk as u64, i as u64) as f32;
            }
        }
        let mut gen = gls_serve::stats::rng::XorShift128::new(trial ^ 0xBEE);
        let q = gls_serve::testkit::gen_categorical(&mut gen, n);
        let p = gls_serve::testkit::gen_categorical(&mut gen, n);
        let qm: Vec<f32> = (0..k * n).map(|idx| q.prob(idx % n) as f32).collect();
        let pm: Vec<f32> = (0..k * n).map(|idx| p.prob(idx % n) as f32).collect();

        let lit = |data: &[f32]| {
            xla::Literal::vec1(data).reshape(&[k as i64, n as i64]).unwrap()
        };
        let outs = execute_tuple(&exe, &[lit(&u), lit(&qm), lit(&pm)]).unwrap();
        let y_artifact = outs[0].to_vec::<i32>().unwrap()[0] as usize;
        let xs_artifact: Vec<i32> = outs[1].to_vec().unwrap();

        // Native recomputation in f32 (matching the kernel's dtype) so the
        // argmins compare exactly.
        let mut y_best = f32::INFINITY;
        let mut y_arg = 0usize;
        let mut x_best = vec![f32::INFINITY; k];
        let mut x_arg = vec![0usize; k];
        for kk in 0..k {
            for i in 0..n {
                let s = -(u[kk * n + i]).ln();
                let qv = q.prob(i) as f32;
                let pv = p.prob(i) as f32;
                if qv > 0.0 && s / qv < y_best {
                    y_best = s / qv;
                    y_arg = i;
                }
                if pv > 0.0 && s / pv < x_best[kk] {
                    x_best[kk] = s / pv;
                    x_arg[kk] = i;
                }
            }
        }
        assert_eq!(y_artifact, y_arg, "trial {trial}: Y mismatch");
        for kk in 0..k {
            assert_eq!(xs_artifact[kk] as usize, x_arg[kk], "trial {trial}: X{kk} mismatch");
        }
    }
}
