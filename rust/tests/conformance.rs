//! Statistical conformance suite (built on `testkit`'s chi-square
//! helpers): the distributional guarantees the kernel port must *preserve*,
//! complementing the bit-exactness properties in `tests/kernel_parity.rs`.
//!
//! 1. **Target-marginal preservation.** Every verifier in the registry
//!    (`spec::all_verifiers`) emits first tokens distributed exactly as the
//!    target q — chi-squared goodness-of-fit over tens of thousands of
//!    verified blocks with engine-consistent coupled drafting.
//! 2. **Drafter invariance.** At fixed seeds, the GLS family and Daliri
//!    ignore draft-*distribution* swaps entirely (Def. 1), and the strongly
//!    invariant schemes emit identical token values even when the drafts
//!    are re-drawn from a different drafter model (Def. 2 — only the
//!    stopping point may move).
//! 3. **Adversarial drafters.** The drafter-*dependent* rejection baselines
//!    (SpecInfer, SpecTr, single-draft) must still reproduce q against
//!    point-mass and heavily misaligned drafters.
//!
//! All seeds are fixed: a chi-square crossing here is a real marginal
//! distortion (e.g. a kernel port consuming the wrong RNG coordinates),
//! not sampling noise.

use gls_serve::spec::types::{BlockInput, BlockVerifier, Categorical, VerifierKind};
use gls_serve::spec::{all_verifiers, make_verifier};
use gls_serve::stats::rng::{CounterRng, XorShift128};
use gls_serve::testkit::{assert_marginal, gen_categorical};

/// Build one speculative block with engine-consistent coupled drafting:
/// lane k's token at position j comes from the shared-randomness race at
/// `(slot0 + j, k)` — i.i.d. across lanes (the shape SpecTr requires),
/// coupled to the verifier the way `SpecDecodeEngine` couples them.
fn coupled_block(
    p: &[Categorical],
    q: &[Categorical],
    k: usize,
    rng: &CounterRng,
    slot0: u64,
) -> BlockInput {
    let l = p.len();
    debug_assert_eq!(q.len(), l + 1);
    let mut draft_tokens = vec![Vec::with_capacity(l); k];
    for kk in 0..k {
        for j in 0..l {
            draft_tokens[kk].push(p[j].sample_race(rng, slot0 + j as u64, kk as u64) as u32);
        }
    }
    BlockInput {
        draft_tokens: draft_tokens.into(),
        draft_dists: vec![p.to_vec(); k],
        target_dists: vec![q.to_vec(); k],
    }
}

#[test]
fn every_verifier_preserves_target_marginal() {
    // The defining exactness property of speculative decoding: whatever
    // the drafts, the first emitted token is a sample from q. Runs every
    // registered verifier through the same harness so a kernel port that
    // distorts the marginal (or a future verifier that skips conformance)
    // fails here by name.
    let n = 6;
    let k = 3;
    let l = 1;
    let trials = 20_000usize;
    let mut gen = XorShift128::new(0xC0F1);
    let p: Vec<Categorical> = (0..l).map(|_| gen_categorical(&mut gen, n)).collect();
    let q: Vec<Categorical> = (0..=l).map(|_| gen_categorical(&mut gen, n)).collect();
    for (vi, v) in all_verifiers().iter().enumerate() {
        let rng = CounterRng::new(0x5EED + 1000 * vi as u64);
        let mut counts = vec![0usize; n];
        for t in 0..trials {
            let slot0 = (t as u64) * (l as u64 + 1);
            let input = coupled_block(&p, &q, k, &rng, slot0);
            let out = v.verify_block(&input, &rng, slot0);
            counts[out.tokens[0] as usize] += 1;
        }
        assert_marginal(v.kind().name(), &counts, &q[0], trials);
    }
}

#[test]
fn invariant_verifiers_ignore_draft_distribution_swaps() {
    // Def. 1 at fixed seeds: replace every draft distribution wholesale
    // (tokens held fixed) — the GLS family and Daliri must emit the
    // bit-identical BlockOutput.
    for seed in 0..30u64 {
        let mut gen = XorShift128::new(seed ^ 0xDA11);
        let n = 7;
        let k = 2;
        let l = 3;
        let p: Vec<Categorical> = (0..l).map(|_| gen_categorical(&mut gen, n)).collect();
        let q: Vec<Categorical> = (0..=l).map(|_| gen_categorical(&mut gen, n)).collect();
        let rng = CounterRng::new(seed);
        let input = coupled_block(&p, &q, k, &rng, 0);
        let mut swapped = input.clone();
        for kk in 0..k {
            for j in 0..l {
                swapped.draft_dists[kk][j] = gen_categorical(&mut gen, n);
            }
        }
        for &vk in &[VerifierKind::Gls, VerifierKind::GlsStrong, VerifierKind::Daliri] {
            let v = make_verifier(vk);
            assert_eq!(
                v.verify_block(&input, &rng, 0),
                v.verify_block(&swapped, &rng, 0),
                "{vk:?} output depends on draft distributions (seed {seed})"
            );
        }
    }
}

#[test]
fn strongly_invariant_outputs_identical_across_drafters() {
    // Def. 2 at fixed seeds: re-draft from a *different* drafter model —
    // the draft tokens change, but the token values GlsStrong and Daliri
    // emit are a function of (targets, randomness) only, so the emitted
    // prefixes must agree up to the shorter stopping point. Conditional
    // GLS shares the guarantee at the first position (active = all drafts).
    for seed in 0..30u64 {
        let mut gen = XorShift128::new(seed ^ 0x57F0);
        let n = 6;
        let k = 2;
        let l = 3;
        let p_a: Vec<Categorical> = (0..l).map(|_| gen_categorical(&mut gen, n)).collect();
        let p_b: Vec<Categorical> = (0..l).map(|_| gen_categorical(&mut gen, n)).collect();
        let q: Vec<Categorical> = (0..=l).map(|_| gen_categorical(&mut gen, n)).collect();
        let rng = CounterRng::new(7000 + seed);
        let input_a = coupled_block(&p_a, &q, k, &rng, 0);
        let input_b = coupled_block(&p_b, &q, k, &rng, 0);
        for &vk in &[VerifierKind::GlsStrong, VerifierKind::Daliri] {
            let v = make_verifier(vk);
            let a = v.verify_block(&input_a, &rng, 0);
            let b = v.verify_block(&input_b, &rng, 0);
            let m = a.tokens.len().min(b.tokens.len());
            assert_eq!(
                &a.tokens[..m],
                &b.tokens[..m],
                "{vk:?} emitted different token values under a drafter swap (seed {seed})"
            );
        }
        let v = make_verifier(VerifierKind::Gls);
        assert_eq!(
            v.verify_block(&input_a, &rng, 0).tokens[0],
            v.verify_block(&input_b, &rng, 0).tokens[0],
            "conditional GLS first token depends on the drafter (seed {seed})"
        );
    }
}

#[test]
fn rejection_baselines_preserve_marginal_with_adversarial_drafters() {
    // SpecInfer / SpecTr / single-draft consume the drafter's probabilities
    // in their acceptance tests — the exactness proof must hold for *any*
    // drafter, so hammer them with the two worst shapes: a point mass and a
    // near-point-mass concentrated away from q's bulk.
    let n = 6;
    let k = 2;
    let l = 1;
    let trials = 20_000usize;
    let mut gen = XorShift128::new(0xAD55);
    let q: Vec<Categorical> = (0..=l).map(|_| gen_categorical(&mut gen, n)).collect();
    let drafters: Vec<(&str, Categorical)> = vec![
        ("delta", Categorical::delta(n, 2)),
        (
            "misaligned",
            Categorical::new(vec![0.002, 0.002, 0.002, 0.002, 0.002, 0.99]),
        ),
    ];
    for (di, (label, p0)) in drafters.iter().enumerate() {
        let p = vec![p0.clone(); l];
        for (vi, &vk) in [VerifierKind::SpecInfer, VerifierKind::SpecTr, VerifierKind::SingleDraft]
            .iter()
            .enumerate()
        {
            let v = make_verifier(vk);
            let rng = CounterRng::new(0xBA5E + 1000 * vi as u64 + 100 * di as u64);
            let mut counts = vec![0usize; n];
            for t in 0..trials {
                let slot0 = (t as u64) * (l as u64 + 1);
                let input = coupled_block(&p, &q, k, &rng, slot0);
                let out = v.verify_block(&input, &rng, slot0);
                counts[out.tokens[0] as usize] += 1;
            }
            assert_marginal(&format!("{}-vs-{label}", vk.name()), &counts, &q[0], trials);
        }
    }
}

#[test]
fn replay_reproduces_identical_outputs_after_interleaved_work() {
    // Drafter invariance is only useful if it composes with determinism:
    // running the same verifier twice (fresh thread-local state, reused
    // workspaces, any interleaving with other verifiers) must reproduce
    // the identical output — the replay-audit property the coordinator
    // relies on.
    let mut gen = XorShift128::new(0x2E91);
    let n = 8;
    let l = 4;
    let p: Vec<Categorical> = (0..l).map(|_| gen_categorical(&mut gen, n)).collect();
    let q: Vec<Categorical> = (0..=l).map(|_| gen_categorical(&mut gen, n)).collect();
    let rng = CounterRng::new(404);
    let input = coupled_block(&p, &q, 1, &rng, 0);
    let first = make_verifier(VerifierKind::Daliri).verify_block(&input, &rng, 0);
    // Interleave unrelated kernel work, then replay.
    for v in all_verifiers() {
        v.verify_block(&input, &rng, 1000);
    }
    let replay = make_verifier(VerifierKind::Daliri).verify_block(&input, &rng, 0);
    assert_eq!(first, replay, "replay diverged after interleaved kernel work");
}
