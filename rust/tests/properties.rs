//! Property-based tests (in-house `testkit::forall`) on the paper's
//! mathematical guarantees and the coordinator's state invariants.

use gls_serve::spec::gls::{sample_gls, sample_gls_diverse, GlsVerifier};
use gls_serve::spec::types::{BlockInput, BlockVerifier, Categorical};
use gls_serve::spec::{all_verifiers, lml, optimal};
use gls_serve::stats::rng::{CounterRng, XorShift128};
use gls_serve::testkit::{forall, gen_categorical, gen_peaked_categorical, gen_sparse_categorical};

#[derive(Debug)]
struct Instance {
    p: Categorical,
    q: Categorical,
    k: usize,
}

fn gen_instance(rng: &mut XorShift128) -> Instance {
    let n = 2 + rng.next_below(12) as usize;
    let k = 1 + rng.next_below(8) as usize;
    let sparse = rng.next_below(4) == 0;
    let (p, q) = if sparse {
        let support = 1 + rng.next_below(n as u64) as usize;
        (gen_sparse_categorical(rng, n, support.max(2)), gen_categorical(rng, n))
    } else if rng.next_below(2) == 0 {
        (gen_peaked_categorical(rng, n, 0.7), gen_peaked_categorical(rng, n, 1.3))
    } else {
        (gen_categorical(rng, n), gen_categorical(rng, n))
    };
    Instance { p, q, k }
}

#[test]
fn prop_lml_bound_is_valid_lower_bound() {
    // Empirical acceptance of GLS ≥ Theorem 1 bound, across random shapes
    // including sparse supports and peaked (LLM-like) distributions.
    forall(101, 30, gen_instance, |inst| {
        let rng = CounterRng::new(7);
        let trials = 6000;
        let hits = (0..trials)
            .filter(|&t| sample_gls(&inst.p, &inst.q, inst.k, &rng, t as u64).accept)
            .count();
        let emp = hits as f64 / trials as f64;
        let bound = lml::theorem1_bound(&inst.p, &inst.q, inst.k);
        if emp + 0.03 < bound {
            return Err(format!("empirical {emp:.4} < LML bound {bound:.4} (K={})", inst.k));
        }
        Ok(())
    });
}

#[test]
fn prop_acceptance_never_exceeds_upper_bound() {
    forall(202, 30, gen_instance, |inst| {
        let rng = CounterRng::new(9);
        let trials = 6000;
        let hits = (0..trials)
            .filter(|&t| sample_gls(&inst.p, &inst.q, inst.k, &rng, t as u64).accept)
            .count();
        let emp = hits as f64 / trials as f64;
        let ub = optimal::upper_bound(&inst.p, &inst.q, inst.k);
        if emp > ub + 0.03 {
            return Err(format!("empirical {emp:.4} > optimal bound {ub:.4}"));
        }
        Ok(())
    });
}

#[test]
fn prop_gls_marginals_preserved() {
    // Prop. 1 over random instances: Y ~ q and X^(k) ~ p (chi-square).
    forall(303, 12, gen_instance, |inst| {
        let rng = CounterRng::new(13);
        let trials = 20_000usize;
        let n = inst.p.len();
        let mut yc = vec![0usize; n];
        let mut xc = vec![0usize; n];
        for t in 0..trials {
            let out = sample_gls(&inst.p, &inst.q, inst.k, &rng, t as u64);
            yc[out.y] += 1;
            xc[out.xs[0]] += 1;
        }
        let chi = |counts: &[usize], dist: &Categorical| {
            let mut c2 = 0.0;
            let mut dof = 0;
            for i in 0..n {
                let e = dist.prob(i) * trials as f64;
                if e > 4.0 {
                    c2 += (counts[i] as f64 - e).powi(2) / e;
                    dof += 1;
                }
            }
            (c2, dof)
        };
        let (cy, dy) = chi(&yc, &inst.q);
        let (cx, dx) = chi(&xc, &inst.p);
        let lim = |d: usize| d as f64 + 5.0 * (2.0 * d as f64).sqrt() + 12.0;
        if cy > lim(dy) {
            return Err(format!("Y marginal chi2 {cy:.1} (dof {dy})"));
        }
        if cx > lim(dx) {
            return Err(format!("X marginal chi2 {cx:.1} (dof {dx})"));
        }
        Ok(())
    });
}

#[test]
fn prop_diverse_proposals_marginals_preserved() {
    // Prop. 5: per-draft marginals with heterogeneous proposals.
    forall(404, 10, |rng| {
        let n = 2 + rng.next_below(8) as usize;
        let k = 1 + rng.next_below(4) as usize;
        let ps: Vec<Categorical> = (0..k).map(|_| gen_categorical(rng, n)).collect();
        let q = gen_categorical(rng, n);
        (ps, q)
    }, |(ps, q)| {
        let rng = CounterRng::new(21);
        let trials = 15_000usize;
        let n = q.len();
        let k = ps.len();
        let mut xc = vec![vec![0usize; n]; k];
        for t in 0..trials {
            let out = sample_gls_diverse(ps, q, &rng, t as u64);
            for (kk, &x) in out.xs.iter().enumerate() {
                xc[kk][x] += 1;
            }
        }
        for kk in 0..k {
            for i in 0..n {
                let f = xc[kk][i] as f64 / trials as f64;
                if (f - ps[kk].prob(i)).abs() > 0.03 {
                    return Err(format!("draft {kk} marginal off at {i}: {f} vs {}", ps[kk].prob(i)));
                }
            }
        }
        Ok(())
    });
}

#[derive(Debug)]
struct BlockCase {
    input: BlockInput,
    seed: u64,
}

fn gen_block(rng: &mut XorShift128) -> BlockCase {
    let n = 3 + rng.next_below(8) as usize;
    let k = 1 + rng.next_below(5) as usize;
    let l = 1 + rng.next_below(5) as usize;
    let seed = rng.next_u64();
    let p: Vec<Categorical> = (0..l).map(|_| gen_categorical(rng, n)).collect();
    let q: Vec<Categorical> = (0..=l).map(|_| gen_categorical(rng, n)).collect();
    let crng = CounterRng::new(seed);
    let mut draft_tokens = vec![Vec::with_capacity(l); k];
    for kk in 0..k {
        for j in 0..l {
            draft_tokens[kk].push(p[j].sample_race(&crng, j as u64, kk as u64) as u32);
        }
    }
    BlockCase {
        input: BlockInput {
            draft_tokens: draft_tokens.into(),
            draft_dists: vec![p; k],
            target_dists: vec![q; k],
        },
        seed,
    }
}

#[test]
fn prop_every_verifier_emits_valid_blocks() {
    // Structural invariants across all verifiers and random blocks:
    // τ = accepted + 1, accepted ≤ L, accepted prefix matches a draft,
    // tokens within the alphabet, determinism. Iterates the registry
    // (`spec::all_verifiers`) rather than a hand-maintained kind list, so
    // a newly ported verifier cannot be silently omitted from coverage.
    forall(505, 40, gen_block, |case| {
        for v in all_verifiers() {
            let vk = v.kind();
            let rng = CounterRng::new(case.seed);
            let out = v.verify_block(&case.input, &rng, 0);
            let out2 = v.verify_block(&case.input, &rng, 0);
            if out != out2 {
                return Err(format!("{vk:?} nondeterministic"));
            }
            let l = case.input.block_len();
            let n = case.input.target_dists[0][0].len() as u32;
            if out.tokens.len() != out.accepted + 1 {
                return Err(format!("{vk:?}: τ {} != accepted {} + 1", out.tokens.len(), out.accepted));
            }
            if out.accepted > l {
                return Err(format!("{vk:?}: accepted {} > L {l}", out.accepted));
            }
            if out.tokens.iter().any(|&t| t >= n) {
                return Err(format!("{vk:?}: token out of alphabet"));
            }
            if let Some(sd) = out.surviving_draft {
                let lane = if vk.is_single_draft() { 0 } else { sd };
                for j in 0..out.accepted {
                    if case.input.draft_tokens[lane][j] != out.tokens[j] {
                        return Err(format!("{vk:?}: accepted prefix mismatch"));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_gls_conditional_invariance_under_draft_dist_swaps() {
    // Def. 1 as a property: replace draft distributions (not tokens), the
    // conditional-GLS output must not change at all.
    forall(606, 40, gen_block, |case| {
        let v = GlsVerifier::conditional();
        let rng = CounterRng::new(case.seed ^ 0xAB);
        let base = v.verify_block(&case.input, &rng, 3);
        let mut swapped = case.input.clone();
        let mut gen = XorShift128::new(case.seed ^ 0xCD);
        let n = case.input.target_dists[0][0].len();
        for kk in 0..swapped.k() {
            for j in 0..swapped.block_len() {
                swapped.draft_dists[kk][j] = gen_categorical(&mut gen, n);
            }
        }
        let out = v.verify_block(&swapped, &rng, 3);
        if base != out {
            return Err("conditional invariance violated".into());
        }
        Ok(())
    });
}

#[test]
fn prop_kv_cache_never_corrupts_under_random_ops() {
    // Coordinator state invariant under adversarial op sequences.
    use gls_serve::coordinator::kv::PagedKvCache;
    forall(707, 20, |rng| rng.next_u64(), |&seed| {
        let mut rng = XorShift128::new(seed);
        let total = 16 + rng.next_below(64) as usize;
        let page = 1 + rng.next_below(32) as usize;
        let mut kv = PagedKvCache::new(total, page);
        let mut live: Vec<(u64, bool)> = Vec::new(); // (id, has_reservation)
        let mut next = 0u64;
        for _ in 0..500 {
            match rng.next_below(4) {
                0 => {
                    let prompt = 1 + rng.next_below(40) as usize;
                    let max = prompt + rng.next_below(40) as usize;
                    if kv.register(next, prompt, max, 6).is_ok() {
                        live.push((next, false));
                    }
                    next += 1;
                }
                1 => {
                    if let Some(e) = live.iter_mut().find(|(_, r)| !*r) {
                        if kv.reserve_block(e.0, 1 + rng.next_below(6) as usize).is_ok() {
                            e.1 = true;
                        }
                    }
                }
                2 => {
                    if let Some(e) = live.iter_mut().find(|(_, r)| *r) {
                        kv.commit(e.0, rng.next_below(2) as usize).unwrap();
                        e.1 = false;
                    }
                }
                _ => {
                    if !live.is_empty() {
                        let i = rng.next_below(live.len() as u64) as usize;
                        let (id, _) = live.swap_remove(i);
                        kv.release(id).unwrap();
                    }
                }
            }
            kv.check_invariants().map_err(|e| format!("seed {seed}: {e}"))?;
        }
        Ok(())
    });
}

#[test]
fn prop_spectr_calibration_is_exact_coupling() {
    // K-SEQ with calibrated γ preserves the target marginal — checked via
    // total-variation of the analytic output law vs q (no sampling noise):
    // law(y) = c·min(p, q/γ) + residual mass (see spectr.rs derivation).
    forall(808, 60, |rng| {
        let n = 2 + rng.next_below(10) as usize;
        let k = 1 + rng.next_below(8) as usize;
        (gen_categorical(rng, n), gen_categorical(rng, n), k)
    }, |(p, q, k)| {
        let plan = gls_serve::spec::spectr::calibrate(p, q, *k);
        let s = plan.s;
        let c = plan.c;
        let n = p.len();
        let mut law = vec![0.0; n];
        for y in 0..n {
            law[y] = c * p.prob(y).min(q.prob(y) / plan.gamma);
        }
        // All candidates rejected with probability (1-s)^K = 1 - c·s, and
        // the residual distribution then fires: law += (1-s)^K · res(y).
        let res_scale = (1.0 - s).powi(*k as i32);
        if let Some(r) = &plan.residual {
            for y in 0..n {
                law[y] += res_scale * r.prob(y);
            }
        }
        let tv: f64 = 0.5 * (0..n).map(|y| (law[y] - q.prob(y)).abs()).sum::<f64>();
        if tv > 1e-6 {
            return Err(format!("K-SEQ law deviates from q: TV {tv:.2e} (γ={})", plan.gamma));
        }
        Ok(())
    });
}
