//! Cross-module integration tests (artifact-free: SimLm backends).
//!
//! These exercise whole-system behaviours the unit tests cannot: verifier
//! comparisons under one engine, paper-property audits (drafter
//! invariance end-to-end, order sensitivity), serving-stack round trips,
//! and the compression pipelines end to end.

use gls_serve::coordinator::engine::SpecDecodeEngine;
use gls_serve::coordinator::kv::PagedKvCache;
use gls_serve::coordinator::router::RoutingPolicy;
use gls_serve::coordinator::scheduler::Scheduler;
use gls_serve::coordinator::sequence::{Request, SequenceState};
use gls_serve::coordinator::server::Server;
use gls_serve::coordinator::{EngineConfig, ServerConfig};
use gls_serve::model::backend::ModelPair;
use gls_serve::model::sampling::SamplingParams;
use gls_serve::model::sim::SimLm;
use gls_serve::spec::types::VerifierKind;
use gls_serve::workload::suites::SUITES;

fn mk_engine(
    verifier: VerifierKind,
    k: usize,
    l: usize,
    divergence: f32,
    seed: u64,
    draft_temps: &[f64],
    target_temp: f64,
) -> SpecDecodeEngine {
    let (draft, target) = SimLm::pair(48, seed, divergence);
    let draft_params = if draft_temps.is_empty() {
        vec![SamplingParams::new(1.0, Some(50))]
    } else {
        draft_temps.iter().map(|&t| SamplingParams::new(t, Some(50))).collect()
    };
    let cfg = EngineConfig {
        num_drafts: k,
        block_len: l,
        verifier,
        target_params: SamplingParams::new(target_temp, Some(50)),
        draft_params,
        max_seq_len: 512,
        seed,
        ..EngineConfig::default()
    };
    SpecDecodeEngine::new(
        cfg,
        ModelPair::new(Box::new(draft), Box::new(target)),
        PagedKvCache::new(4096, 16),
    )
}

fn be_of(engine: &mut SpecDecodeEngine, prompts: usize, new_tokens: usize) -> f64 {
    let mut total = 0.0;
    for i in 0..prompts {
        let req = Request::new(i as u64, vec![i as u32, 1, 2], new_tokens);
        let mut seq = SequenceState::from_request(&req);
        engine.decode_sequence(&mut seq);
        total += seq.block_efficiency();
    }
    total / prompts as f64
}

#[test]
fn multi_draft_schemes_cluster_and_beat_single_draft_iid() {
    // Table 1's qualitative content: with i.i.d. drafts, GLS ≈ SpecInfer ≈
    // SpecTr on BE, all above the K=1 single-draft baseline and the Daliri
    // single-draft coupling.
    let run = |vk: VerifierKind, k: usize| {
        let mut eng = mk_engine(vk, k, 4, 2.0, 11, &[], 1.0);
        be_of(&mut eng, 12, 40)
    };
    let gls = run(VerifierKind::Gls, 8);
    let specinfer = run(VerifierKind::SpecInfer, 8);
    let spectr = run(VerifierKind::SpecTr, 8);
    let single = run(VerifierKind::SingleDraft, 1);
    let daliri = run(VerifierKind::Daliri, 1);
    assert!(gls > single + 0.1, "gls {gls} vs single {single}");
    assert!(specinfer > single + 0.1);
    assert!(spectr > single + 0.1);
    assert!((gls - specinfer).abs() < 0.5, "gls {gls} vs specinfer {specinfer}");
    assert!((gls - spectr).abs() < 0.5, "gls {gls} vs spectr {spectr}");
    assert!(gls > daliri, "gls {gls} vs daliri {daliri}");
}

#[test]
fn block_efficiency_monotone_in_k_for_gls() {
    let be: Vec<f64> = [1, 2, 4, 8]
        .iter()
        .map(|&k| {
            let mut eng = mk_engine(VerifierKind::Gls, k, 4, 2.0, 5, &[], 1.0);
            be_of(&mut eng, 10, 40)
        })
        .collect();
    for w in be.windows(2) {
        assert!(w[1] >= w[0] - 0.08, "BE not (weakly) monotone: {be:?}");
    }
    assert!(be[3] > be[0] + 0.1, "K=8 should clearly beat K=1: {be:?}");
}

#[test]
fn gls_order_insensitive_specinfer_order_sensitive() {
    // Table 2's asymmetry: swap two mismatched drafters' temperatures and
    // GLS's BE moves much less than SpecInfer's.
    let run = |vk: VerifierKind, temps: &[f64]| {
        let mut eng = mk_engine(vk, 2, 5, 2.0, 23, temps, 2.0);
        be_of(&mut eng, 16, 40)
    };
    let gls_a = run(VerifierKind::Gls, &[0.5, 2.0]);
    let gls_b = run(VerifierKind::Gls, &[2.0, 0.5]);
    let si_a = run(VerifierKind::SpecInfer, &[0.5, 2.0]);
    let si_b = run(VerifierKind::SpecInfer, &[2.0, 0.5]);
    let gls_gap = (gls_a - gls_b).abs();
    let si_gap = (si_a - si_b).abs();
    // GLS treats drafts symmetrically; SpecInfer favors the first.
    assert!(
        gls_gap <= si_gap + 0.05,
        "gls gap {gls_gap:.3} vs specinfer gap {si_gap:.3} (a/b: {gls_a:.2}/{gls_b:.2} vs {si_a:.2}/{si_b:.2})"
    );
}

#[test]
fn drafter_invariance_audit_end_to_end() {
    // Def. 1 at the system level: run the GLS engine twice with the same
    // seed but different draft models. Whenever the two runs have produced
    // identical draft token matrices for a block, their outputs match.
    // We force that by replaying with divergence-0 drafts (draft == target
    // in run A; a *different but coupled* drafter in run B would change
    // tokens, so instead we verify the pure verifier path in-unit) —
    // here we check the weaker end-to-end consequence: same seed + same
    // draft model ⇒ bit-identical outputs (full determinism).
    let out = |_which: u8| {
        let mut eng = mk_engine(VerifierKind::Gls, 4, 4, 1.5, 99, &[], 1.0);
        let req = Request::new(1, vec![3, 1, 4], 32);
        let mut seq = SequenceState::from_request(&req);
        eng.decode_sequence(&mut seq);
        seq.tokens
    };
    assert_eq!(out(0), out(1), "engine must be deterministic per seed");
}

#[test]
fn sequence_correctness_chi_square_all_multi_draft_verifiers() {
    // Prop. 3-style check at engine level for every verifier: the marginal
    // of the first generated token matches the target model's next-token
    // distribution (temperature + top-k applied).
    let vocab = 24;
    let trials = 3000u64;
    for &vk in &[VerifierKind::Gls, VerifierKind::GlsStrong, VerifierKind::SpecInfer, VerifierKind::SpecTr]
    {
        let (draft, target) = SimLm::pair(vocab, 31, 2.5);
        let q_expect = gls_serve::spec::types::Categorical::from_logits(
            &target.logits_at(&[2, 7]),
            1.0,
            None,
        );
        let cfg = EngineConfig {
            num_drafts: 3,
            block_len: 2,
            verifier: vk,
            target_params: SamplingParams::new(1.0, None),
            draft_params: vec![SamplingParams::new(1.0, None)],
            max_seq_len: 64,
            seed: 1234,
            ..EngineConfig::default()
        };
        let mut eng = SpecDecodeEngine::new(
            cfg,
            ModelPair::new(Box::new(draft), Box::new(target)),
            PagedKvCache::new(4096, 16),
        );
        let mut counts = vec![0usize; vocab];
        for lane in 0..trials {
            let req = Request::new(lane, vec![2, 7], 1);
            let mut seq = SequenceState::from_request(&req);
            eng.decode_sequence(&mut seq);
            counts[seq.tokens[2] as usize] += 1;
        }
        let mut chi2 = 0.0;
        let mut dof = 0;
        for i in 0..vocab {
            let e = q_expect.prob(i) * trials as f64;
            if e > 2.0 {
                chi2 += (counts[i] as f64 - e).powi(2) / e;
                dof += 1;
            }
        }
        // 99.9th percentile of chi2(dof) ≈ dof + 3*sqrt(2 dof) + slack.
        let limit = dof as f64 + 4.0 * (2.0 * dof as f64).sqrt() + 10.0;
        assert!(chi2 < limit, "{vk:?}: chi2 {chi2:.1} over dof {dof} (limit {limit:.1})");
    }
}

#[test]
fn serving_stack_round_trip_all_policies() {
    for policy in [RoutingPolicy::RoundRobin, RoutingPolicy::LeastLoaded] {
        let sc = ServerConfig { workers: 3, ..ServerConfig::default() };
        let ec = EngineConfig {
            verifier: VerifierKind::Gls,
            num_drafts: 4,
            block_len: 4,
            max_seq_len: 256,
            ..EngineConfig::default()
        };
        let workload: Vec<(Vec<u32>, usize)> =
            (0..24).map(|i| (vec![i as u32, 2, 3], 12)).collect();
        let report = Server::serve_all(
            &sc,
            &ec,
            policy,
            |_| {
                let (d, t) = SimLm::pair(32, 8, 1.5);
                ModelPair::new(Box::new(d), Box::new(t))
            },
            workload,
        );
        assert_eq!(report.results.len(), 24);
        for r in &report.results {
            assert_eq!(r.tokens.len(), 15, "policy {policy:?}");
        }
        assert!(report.metrics.block_efficiency() > 1.0);
    }
}

#[test]
fn scheduler_under_pressure_matches_unconstrained_outputs() {
    // KV pressure changes *scheduling*, never *content*: outputs under a
    // tiny KV budget equal outputs under an ample one.
    let run = |pages: usize| {
        let (d, t) = SimLm::pair(32, 77, 1.5);
        let cfg = EngineConfig {
            verifier: VerifierKind::Gls,
            num_drafts: 2,
            block_len: 4,
            max_seq_len: 128,
            ..EngineConfig::default()
        };
        let mut eng = SpecDecodeEngine::new(
            cfg,
            ModelPair::new(Box::new(d), Box::new(t)),
            PagedKvCache::new(pages, 16),
        );
        let mut sched = Scheduler::new(8);
        for i in 0..6 {
            sched.submit(Request::new(i, vec![1, 2, 3], 16));
        }
        let mut results = sched.run_to_completion(&mut eng);
        results.sort_by_key(|r| r.id);
        results.into_iter().map(|r| r.tokens).collect::<Vec<_>>()
    };
    assert_eq!(run(4), run(4096));
}

#[test]
fn suite_difficulty_ordering_holds() {
    // The calibrated suites must order single-draft BE the same way the
    // paper's datasets do: gsm8k easiest, drop hardest.
    let be: Vec<(f64, &str)> = SUITES
        .iter()
        .map(|s| {
            let pair = s.model_pair(48, 3);
            let cfg = EngineConfig {
                verifier: VerifierKind::SingleDraft,
                num_drafts: 1,
                block_len: 4,
                target_params: SamplingParams::new(1.0, Some(50)),
                draft_params: vec![SamplingParams::new(1.0, Some(50))],
                max_seq_len: 512,
                seed: 17,
                ..EngineConfig::default()
            };
            let mut eng = SpecDecodeEngine::new(cfg, pair, PagedKvCache::new(4096, 16));
            (be_of(&mut eng, 10, 40), s.name)
        })
        .collect();
    let gsm = be.iter().find(|(_, n)| *n == "gsm8k-sim").unwrap().0;
    let drop = be.iter().find(|(_, n)| *n == "drop-sim").unwrap().0;
    assert!(gsm > drop, "difficulty ordering broken: {be:?}");
}

#[test]
fn compression_pipelines_end_to_end() {
    use gls_serve::compression::codec::RandomnessMode;
    use gls_serve::compression::gaussian::{run_gaussian, GaussianSource};
    use gls_serve::compression::image::{run_image, synthetic_digits, AnalyticVae};

    // Gaussian: K=3 GLS beats baseline at low rate, distortion sane.
    let g_gls = run_gaussian(
        GaussianSource::paper_default(0.005),
        3,
        4,
        1 << 10,
        300,
        3,
        RandomnessMode::Independent,
    );
    let g_bl = run_gaussian(
        GaussianSource::paper_default(0.005),
        3,
        4,
        1 << 10,
        300,
        3,
        RandomnessMode::Shared,
    );
    assert!(g_gls.match_rate > g_bl.match_rate, "{} vs {}", g_gls.match_rate, g_bl.match_rate);
    assert!(g_gls.mse < 1.0);

    // Image: pipeline runs and GLS at K=4 beats its own K=1.
    let imgs = synthetic_digits(120, 8);
    let vae = AnalyticVae::fit(&imgs[..80], 4, 0.05, 2);
    let k1 = run_image(&vae, &imgs[80..], 1, 8, 128, 5, RandomnessMode::Independent);
    let k4 = run_image(&vae, &imgs[80..], 4, 8, 128, 5, RandomnessMode::Independent);
    assert!(k4.match_rate >= k1.match_rate);
    assert!(k4.mse <= k1.mse + 1e-3);
}
