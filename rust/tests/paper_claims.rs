//! Direct audits of the paper's stated claims, one test per claim, at the
//! theory level (fast — no engine, no artifacts). Each test cites the
//! paper location it checks.

use gls_serve::spec::gls::{sample_gls, GlsVerifier};
use gls_serve::spec::types::{BlockInput, BlockVerifier, Categorical};
use gls_serve::spec::{lml, optimal, spectr};
use gls_serve::stats::rng::{CounterRng, XorShift128};
use gls_serve::testkit::gen_categorical;

/// §3, motivating example: reusing the same exponentials for a second
/// draft makes X^(2) ≡ X^(1) — no list gain. (Why GLS needs fresh
/// per-draft exponentials coupled through the min at the target.)
#[test]
fn reusing_randomness_gives_identical_drafts() {
    let p = Categorical::new(vec![0.4, 0.6]);
    let rng = CounterRng::new(3);
    for slot in 0..500 {
        let a = p.sample_race(&rng, slot, 0);
        let b = p.sample_race(&rng, slot, 0); // same coordinates
        assert_eq!(a, b);
    }
}

/// Theorem 1 footnote: with a single proposal the LML is identical to the
/// Poisson matching lemma bound Σ_j 1/Σ_i max(q_i/q_j, p_i/p_j).
#[test]
fn lml_k1_equals_pml_formula() {
    let mut gen = XorShift128::new(1);
    for _ in 0..20 {
        let p = gen_categorical(&mut gen, 7);
        let q = gen_categorical(&mut gen, 7);
        let lml1 = lml::theorem1_bound(&p, &q, 1);
        let pml: f64 = (0..7)
            .map(|j| {
                let denom: f64 = (0..7)
                    .map(|i| (q.prob(i) / q.prob(j)).max(p.prob(i) / p.prob(j)))
                    .sum();
                1.0 / denom
            })
            .sum();
        assert!((lml1 - pml).abs() < 1e-12);
    }
}

/// §3 after Thm. 1: "for any j such that q_j > 0 and p_j > 0, the matching
/// probability achieved by GLS approaches 1 for large K."
#[test]
fn conditional_match_approaches_one_in_k() {
    let bound = |k| lml::conditional_bound(0.001, 0.999, k);
    assert!(bound(1) < 0.01);
    assert!(bound(1000) > 0.5);
    assert!(bound(1_000_000) > 0.999);
    // Monotone in K.
    let mut last = 0.0;
    for k in [1, 2, 4, 8, 16, 32, 64] {
        let b = bound(k);
        assert!(b >= last);
        last = b;
    }
}

/// §4.1: identical draft/target distributions with shared randomness give
/// certain acceptance at every K (the coupled races agree).
#[test]
fn aligned_models_always_accept() {
    let mut gen = XorShift128::new(5);
    let q = gen_categorical(&mut gen, 12);
    let rng = CounterRng::new(11);
    for k in [1usize, 3, 8] {
        for slot in 0..300 {
            assert!(sample_gls(&q, &q, k, &rng, slot).accept);
        }
    }
}

/// App. B: the strongly invariant scheme's bound with J active drafts is
/// (J/K) × the conditional scheme's K-draft bound — strictly weaker
/// whenever any draft has been rejected (J < K).
#[test]
fn strong_invariance_bound_strictly_weaker_after_rejection() {
    let mut gen = XorShift128::new(9);
    let p = gen_categorical(&mut gen, 6);
    let q = gen_categorical(&mut gen, 6);
    let k = 6;
    for j_active in 1..k {
        let strong = lml::strong_bound(&p, &q, j_active, k);
        let cond = lml::theorem1_bound(&p, &q, j_active);
        // Conditional scheme with J drafts uses denominators with (J-1)
        // trailing terms; strong pays for all K-1. Strong ≤ conditional.
        assert!(
            strong <= cond + 1e-12,
            "J={j_active}: strong {strong} > conditional {cond}"
        );
    }
}

/// §4.3 / Table 2 mechanism: SpecInfer's acceptance depends on the draft
/// order; GLS's does not (symmetric min over lanes).
#[test]
fn gls_step_is_symmetric_in_lane_permutation() {
    // Permuting which lane holds which draft distribution changes nothing
    // about Y's law because all lanes share the target race symmetrically;
    // with i.i.d. drafts, swapping lane contents leaves the outcome set
    // {X^(k)} unchanged as a multiset.
    let mut gen = XorShift128::new(21);
    let p = gen_categorical(&mut gen, 5);
    let q = gen_categorical(&mut gen, 5);
    let rng = CounterRng::new(8);
    for slot in 0..500 {
        let out = sample_gls(&p, &q, 3, &rng, slot);
        // Y from the joint race equals Y recomputed from the same
        // exponentials regardless of lane labelling (deterministic check).
        let out2 = sample_gls(&p, &q, 3, &rng, slot);
        assert_eq!(out.y, out2.y);
        let mut xs = out.xs.clone();
        let mut xs2 = out2.xs.clone();
        xs.sort_unstable();
        xs2.sort_unstable();
        assert_eq!(xs, xs2);
    }
}

/// §4.2 / Alg. 2 line 12: when every draft diverges at step 1, exactly one
/// token (Y_1) is emitted — the residual-free property that distinguishes
/// GLS from rejection-sampling schemes.
#[test]
fn gls_block_emits_y_even_on_total_rejection() {
    let n = 4;
    let q = Categorical::delta(n, 0); // target insists on symbol 0
    let p = Categorical::delta(n, 1); // drafts insist on symbol 1
    let input = BlockInput {
        draft_tokens: vec![vec![1, 1]; 3].into(),
        draft_dists: vec![vec![p.clone(), p.clone()]; 3],
        target_dists: vec![vec![q.clone(), q.clone(), q.clone()]; 3],
    };
    let out = GlsVerifier::conditional().verify_block(&input, &CounterRng::new(2), 0);
    assert_eq!(out.accepted, 0);
    assert_eq!(out.tokens, vec![0]); // Y_1 sampled from the target
}

/// SpecTr §: K-SEQ's calibrated γ grows with draft/target mismatch and
/// equals 1 under perfect alignment.
#[test]
fn kseq_gamma_tracks_mismatch() {
    let q = Categorical::new(vec![0.7, 0.2, 0.1]);
    let aligned = spectr::calibrate(&q, &q, 8);
    assert!((aligned.gamma - 1.0).abs() < 1e-9);
    let p_bad = Categorical::new(vec![0.05, 0.05, 0.9]);
    let mis = spectr::calibrate(&p_bad, &q, 8);
    assert!(mis.gamma > 1.5, "γ = {}", mis.gamma);
    assert!(mis.gamma <= 8.0 + 1e-9);
}

/// Figure 6 reference: the optimal-coupling value is achievable only with
/// communication — GLS (communication-free) stays below it, yet above the
/// LML bound, on every random instance.
#[test]
fn gls_sandwiched_between_lml_and_optimal() {
    let mut gen = XorShift128::new(31);
    for _ in 0..10 {
        let p = gen_categorical(&mut gen, 6);
        let q = gen_categorical(&mut gen, 6);
        for k in [1usize, 2, 4] {
            let rng = CounterRng::new(77);
            let trials = 12_000;
            let emp = (0..trials)
                .filter(|&t| sample_gls(&p, &q, k, &rng, t as u64).accept)
                .count() as f64
                / trials as f64;
            assert!(emp + 0.03 >= lml::theorem1_bound(&p, &q, k));
            assert!(emp <= optimal::upper_bound(&p, &q, k) + 0.03);
        }
    }
}

/// Prop. 4 mechanism: the bound improves when K·L_max doubles by either
/// factor — decoders and rate are interchangeable in the exponent.
#[test]
fn prop4_k_and_rate_are_interchangeable() {
    let densities: Vec<f64> = (0..1000).map(|i| (i % 7) as f64).collect();
    let a = lml::proposition4_success_bound(&densities, 2, 16);
    let b = lml::proposition4_success_bound(&densities, 4, 8);
    let c = lml::proposition4_success_bound(&densities, 1, 32);
    assert!((a - b).abs() < 1e-12);
    assert!((a - c).abs() < 1e-12);
}
